//! SQL-style MapReduce workloads from the paper's Table 1: Scan Query,
//! Aggregation Query, Join Query (the AMPLab-benchmark-shaped trio).
//!
//! Rows are CSV-ish records `id,category,value,padding\n` generated from
//! the same seeded RNG in real and synthetic modes, so byte accounting
//! agrees across modes.

use crate::mapreduce::{
    CombinerMode, MapOutput, PartitionPlan, ReduceOutput, SystemConfig,
    Workload,
};
use crate::runtime::RtEngine;
use crate::storage::Payload;
use crate::util::rng::Rng;

/// Exact generated row length: fixed-width fields keep real/synthetic
/// byte accounting in lock-step (id:8, cat:4, val:6, pad:14 + commas +
/// newline = 36).
pub const ROW_LEN: f64 = 36.0;

/// Generate ≈`bytes` of rows; `categories` bounds the GROUP BY key.
pub fn gen_rows(bytes: u64, categories: u32, rng: &mut Rng) -> Vec<u8> {
    let mut out = Vec::with_capacity(bytes as usize + 64);
    let mut id = 0u64;
    while (out.len() as u64) < bytes {
        let cat = rng.below(categories.min(9999) as u64);
        let val = rng.below(100_000);
        let pad: String = (0..14)
            .map(|i| (b'a' + ((i as u64 + id) % 26) as u8) as char)
            .collect();
        out.extend_from_slice(
            format!("{id:08},{cat:04},{val:06},{pad}\n").as_bytes(),
        );
        id += 1;
    }
    out.truncate(bytes as usize);
    // Keep the tail row-parseable.
    if let Some(p) = out.iter().rposition(|b| *b == b'\n') {
        out.truncate(p + 1);
        let missing = bytes as usize - out.len();
        out.extend(std::iter::repeat(b' ').take(missing));
    }
    out
}

fn parse_rows(text: &[u8]) -> impl Iterator<Item = (u64, u32, u32)> + '_ {
    text.split(|b| *b == b'\n').filter_map(|line| {
        let mut it = line.split(|b| *b == b',');
        let id = std::str::from_utf8(it.next()?).ok()?.trim();
        if id.is_empty() {
            return None;
        }
        let id: u64 = id.parse().ok()?;
        let cat: u32 = std::str::from_utf8(it.next()?).ok()?.parse().ok()?;
        let val: u32 = std::str::from_utf8(it.next()?).ok()?.parse().ok()?;
        Some((id, cat, val))
    })
}

// ---------------------------------------------------------------------
// Scan Query: SELECT id, value WHERE value < threshold.
// ---------------------------------------------------------------------

/// Table-scan query over synthetic records (Table 1 row "Scan").
pub struct ScanQuery {
    pub categories: u32,
    /// Predicate selectivity (fraction of rows passing).
    pub selectivity: f64,
}

impl ScanQuery {
    pub fn new() -> ScanQuery {
        ScanQuery { categories: 1024, selectivity: 0.9 }
    }

    fn threshold(&self) -> u32 {
        (100_000.0 * self.selectivity) as u32
    }
}

impl Default for ScanQuery {
    fn default() -> Self {
        Self::new()
    }
}

impl Workload for ScanQuery {
    fn name(&self) -> &str {
        "scan_query"
    }

    fn generate_input(&self, bytes: u64, materialize: bool, rng: &mut Rng)
        -> Payload
    {
        if materialize {
            Payload::real(gen_rows(bytes, self.categories, rng))
        } else {
            Payload::synthetic(bytes)
        }
    }

    fn map_split(
        &self,
        split: &Payload,
        plan: &PartitionPlan,
        cfg: &SystemConfig,
        _rt: &mut RtEngine,
        _rng: &mut Rng,
    ) -> MapOutput {
        let parts = plan.parts();
        let ov = cfg.ser.record_overhead();
        match split.contiguous() {
            Some(text) => {
                let mut parts_bytes: Vec<Vec<u8>> = vec![Vec::new(); parts];
                let mut records = 0u64;
                let thr = self.threshold();
                for (id, _cat, val) in parse_rows(&text) {
                    records += 1;
                    if val < thr {
                        let j = plan.route(id);
                        let rec = format!("{id:08},{val:06},padddddddddd"); // 27 B
                        let buf = &mut parts_bytes[j];
                        buf.extend_from_slice(rec.as_bytes());
                        buf.extend(std::iter::repeat(b'x').take(ov as usize));
                    }
                }
                MapOutput {
                    partitions: parts_bytes
                        .into_iter()
                        .map(Payload::real)
                        .collect(),
                    records,
                }
            }
            None => {
                let rows = (split.len() as f64 / ROW_LEN) as u64;
                let kept = (rows as f64 * self.selectivity) as u64;
                let rec_bytes = 27.0 + ov as f64; // projected record = 27 B
                let per_part =
                    (kept as f64 * rec_bytes / parts as f64).round() as u64;
                MapOutput {
                    partitions: (0..parts)
                        .map(|_| Payload::synthetic(per_part))
                        .collect(),
                    records: rows,
                }
            }
        }
    }

    fn reduce_partition(
        &self,
        _part: usize,
        _parts: usize,
        inputs: &[Payload],
        cfg: &SystemConfig,
        _rt: &mut RtEngine,
    ) -> ReduceOutput {
        // Scan reducers strip the framing and emit the projection.
        let in_bytes: u64 = inputs.iter().map(|p| p.len()).sum();
        let ov = cfg.ser.record_overhead();
        let rec = 27.0 + ov as f64;
        let records = (in_bytes as f64 / rec) as u64;
        let out_bytes = (records as f64 * 9.0) as u64; // "id\n" = 9 B
        ReduceOutput { output: Payload::synthetic(out_bytes), records }
    }

    fn map_rate(&self) -> f64 {
        45e6
    }
    fn reduce_rate(&self) -> f64 {
        100e6
    }
}

// ---------------------------------------------------------------------
// Aggregation Query: SELECT cat, AVG(value) GROUP BY cat.
// ---------------------------------------------------------------------

/// Group-by aggregation query through the combine kernel
/// (Table 1 row "Aggregation").
pub struct AggregationQuery {
    pub categories: u32,
}

impl AggregationQuery {
    pub fn new(rt: &RtEngine) -> AggregationQuery {
        AggregationQuery {
            categories: rt.manifest.segments as u32,
        }
    }

    /// Kernel path: segmented sums over one split (real data plane).
    fn combine_rows(&self, text: &[u8], rt: &mut RtEngine)
        -> (Vec<f32>, Vec<f32>, u64)
    {
        let n = rt.manifest.small_batch;
        let mut sums = vec![0f32; rt.manifest.segments];
        let mut cnts = vec![0f32; rt.manifest.segments];
        let mut ids = Vec::with_capacity(n);
        let mut vals = Vec::with_capacity(n);
        let mut rows = 0u64;
        let flush = |ids: &mut Vec<i32>,
                         vals: &mut Vec<f32>,
                         rt: &mut RtEngine,
                         sums: &mut Vec<f32>,
                         cnts: &mut Vec<f32>| {
            if ids.is_empty() {
                return;
            }
            let used = ids.len();
            ids.resize(n, 0);
            vals.resize(n, 0.0);
            let mut mask = vec![0f32; n];
            for m in mask.iter_mut().take(used) {
                *m = 1.0;
            }
            let (s, c) = rt.agg_batch(ids, vals, &mask).expect("agg batch");
            for ((acc, x), (ca, cx)) in
                sums.iter_mut().zip(&s).zip(cnts.iter_mut().zip(&c))
            {
                *acc += x;
                *ca += cx;
            }
            ids.clear();
            vals.clear();
        };
        for (_, cat, val) in parse_rows(text) {
            rows += 1;
            ids.push(cat as i32);
            vals.push(val as f32);
            if ids.len() == n {
                flush(&mut ids, &mut vals, rt, &mut sums, &mut cnts);
            }
        }
        flush(&mut ids, &mut vals, rt, &mut sums, &mut cnts);
        (sums, cnts, rows)
    }
}

impl Workload for AggregationQuery {
    fn name(&self) -> &str {
        "aggregation_query"
    }

    fn generate_input(&self, bytes: u64, materialize: bool, rng: &mut Rng)
        -> Payload
    {
        if materialize {
            Payload::real(gen_rows(bytes, self.categories, rng))
        } else {
            Payload::synthetic(bytes)
        }
    }

    fn map_split(
        &self,
        split: &Payload,
        plan: &PartitionPlan,
        cfg: &SystemConfig,
        rt: &mut RtEngine,
        _rng: &mut Rng,
    ) -> MapOutput {
        let parts = plan.parts();
        let ov = cfg.ser.record_overhead();
        match (split.contiguous(), cfg.combiner) {
            (Some(text), CombinerMode::Kernel) => {
                let (sums, cnts, rows) = self.combine_rows(&text, rt);
                // Partition segments through the plan (hash = the
                // legacy round-robin); 12 B per live segment.
                let mut parts_bytes: Vec<Vec<u8>> = vec![Vec::new(); parts];
                for (seg, (s, c)) in sums.iter().zip(&cnts).enumerate() {
                    if *c > 0.0 {
                        let j = plan.route(seg as u64);
                        parts_bytes[j]
                            .extend_from_slice(&(seg as u32).to_le_bytes());
                        parts_bytes[j].extend_from_slice(&s.to_le_bytes());
                        parts_bytes[j].extend_from_slice(&c.to_le_bytes());
                    }
                }
                MapOutput {
                    partitions: parts_bytes
                        .into_iter()
                        .map(Payload::real)
                        .collect(),
                    records: rows,
                }
            }
            (Some(text), CombinerMode::None) => {
                let mut parts_bytes: Vec<Vec<u8>> = vec![Vec::new(); parts];
                let mut rows = 0u64;
                for (id, cat, val) in parse_rows(&text) {
                    rows += 1;
                    let j = plan.route(cat as u64);
                    let rec = format!("{cat:04},{val:06},{id:08},pad456789"); // 30 B
                    parts_bytes[j].extend_from_slice(rec.as_bytes());
                    parts_bytes[j]
                        .extend(std::iter::repeat(b'x').take(ov as usize));
                }
                MapOutput {
                    partitions: parts_bytes
                        .into_iter()
                        .map(Payload::real)
                        .collect(),
                    records: rows,
                }
            }
            (None, CombinerMode::Kernel) => {
                let rows = (split.len() as f64 / ROW_LEN) as u64;
                let live = self.categories.min(rows as u32) as u64;
                let per_part = live / parts as u64 * 12;
                MapOutput {
                    partitions: (0..parts)
                        .map(|_| Payload::synthetic(per_part))
                        .collect(),
                    records: rows,
                }
            }
            (None, CombinerMode::None) => {
                let rows = (split.len() as f64 / ROW_LEN) as u64;
                // Corral re-keys the near-full row (30 B) + framing:
                // intermediate *exceeds* input (Table 1: 17.4 from 10.5).
                let rec = 30.0 + ov as f64;
                let per_part =
                    (rows as f64 * rec / parts as f64).round() as u64;
                MapOutput {
                    partitions: (0..parts)
                        .map(|_| Payload::synthetic(per_part))
                        .collect(),
                    records: rows,
                }
            }
        }
    }

    fn reduce_partition(
        &self,
        _part: usize,
        _parts: usize,
        inputs: &[Payload],
        cfg: &SystemConfig,
        _rt: &mut RtEngine,
    ) -> ReduceOutput {
        // AVG per category → one tiny record per category
        // (Table 1: 0.01–0.03 GB outputs).
        let live = match cfg.combiner {
            CombinerMode::Kernel => {
                let bytes: u64 = inputs.iter().map(|p| p.len()).sum();
                bytes / 12
            }
            CombinerMode::None => self.categories as u64,
        };
        ReduceOutput {
            output: Payload::synthetic(live * 12),
            records: live,
        }
    }

    fn map_rate(&self) -> f64 {
        40e6
    }
    fn reduce_rate(&self) -> f64 {
        80e6
    }
}

// ---------------------------------------------------------------------
// Join Query: R ⋈ S on key — both tables shuffled in full, tagged.
// ---------------------------------------------------------------------

/// Two-table equi-join query (Table 1 row "Join").
pub struct JoinQuery {
    pub categories: u32,
    /// Output rows per input row (join hit expansion).
    pub match_factor: f64,
}

impl JoinQuery {
    pub fn new() -> JoinQuery {
        JoinQuery { categories: 4096, match_factor: 0.8 }
    }
}

impl Default for JoinQuery {
    fn default() -> Self {
        Self::new()
    }
}

impl Workload for JoinQuery {
    fn name(&self) -> &str {
        "join_query"
    }

    fn generate_input(&self, bytes: u64, materialize: bool, rng: &mut Rng)
        -> Payload
    {
        if materialize {
            Payload::real(gen_rows(bytes, self.categories, rng))
        } else {
            Payload::synthetic(bytes)
        }
    }

    fn map_split(
        &self,
        split: &Payload,
        plan: &PartitionPlan,
        cfg: &SystemConfig,
        _rt: &mut RtEngine,
        _rng: &mut Rng,
    ) -> MapOutput {
        // Joins shuffle *entire* tagged rows regardless of combiner —
        // the paper's Table 1 shows the 4× blow-up (12.5 → 49.6 GB).
        let parts = plan.parts();
        let ov = cfg.ser.record_overhead();
        match split.contiguous() {
            Some(text) => {
                let mut parts_bytes: Vec<Vec<u8>> = vec![Vec::new(); parts];
                let mut rows = 0u64;
                for (id, cat, val) in parse_rows(&text) {
                    rows += 1;
                    let j = plan.route(cat as u64);
                    // Tagged + re-keyed row, shipped for BOTH sides of
                    // the self-join (R side and S side).
                    for side in 0..2u8 {
                        let rec =
                            format!("{side}|{cat:04},{id:08},{val:06},\
12345678901234567890"); // 43 B
                        parts_bytes[j].extend_from_slice(rec.as_bytes());
                        parts_bytes[j]
                            .extend(std::iter::repeat(b'x').take(ov as usize));
                    }
                }
                MapOutput {
                    partitions: parts_bytes
                        .into_iter()
                        .map(Payload::real)
                        .collect(),
                    records: rows,
                }
            }
            None => {
                let rows = (split.len() as f64 / ROW_LEN) as u64;
                let rec = 2.0 * (43.0 + ov as f64); // both sides
                let per_part =
                    (rows as f64 * rec / parts as f64).round() as u64;
                MapOutput {
                    partitions: (0..parts)
                        .map(|_| Payload::synthetic(per_part))
                        .collect(),
                    records: rows,
                }
            }
        }
    }

    fn reduce_partition(
        &self,
        _part: usize,
        _parts: usize,
        inputs: &[Payload],
        cfg: &SystemConfig,
        _rt: &mut RtEngine,
    ) -> ReduceOutput {
        let in_bytes: u64 = inputs.iter().map(|p| p.len()).sum();
        let ov = cfg.ser.record_overhead();
        let rec = 2.0 * (43.0 + ov as f64);
        let rows = in_bytes as f64 / rec;
        let out_rows = rows * self.match_factor;
        // Joined row ≈ 36 B ("cat,idR,idS,valR,valS\n").
        ReduceOutput {
            output: Payload::synthetic((out_rows * 36.0) as u64),
            records: out_rows as u64,
        }
    }

    fn map_rate(&self) -> f64 {
        30e6
    }
    fn reduce_rate(&self) -> f64 {
        40e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::SystemConfig;

    #[test]
    fn rows_parse_back() {
        let mut rng = Rng::new(1);
        let rows = gen_rows(10_000, 100, &mut rng);
        assert_eq!(rows.len(), 10_000);
        let parsed: Vec<_> = parse_rows(&rows).collect();
        assert!(parsed.len() > 150, "only {} rows", parsed.len());
        for (_, cat, val) in &parsed {
            assert!(*cat < 100);
            assert!(*val < 100_000);
        }
    }

    #[test]
    fn scan_selectivity_filters() {
        let mut rt = RtEngine::load(None).unwrap();
        let mut rng = Rng::new(2);
        let q = ScanQuery::new();
        let text = gen_rows(100_000, q.categories, &mut rng);
        let cfg = SystemConfig::corral_lambda();
        let mo = q.map_split(&Payload::real(text), &PartitionPlan::hash(8),
                             &cfg, &mut rt, &mut rng);
        // Intermediate ≈ selectivity × rows × record bytes.
        let rows = mo.records as f64;
        let expect = rows * 0.9 * (27.0 + 31.0);
        let got = mo.total_bytes() as f64;
        assert!((got - expect).abs() / expect < 0.15,
                "got {got} expect {expect}");
    }

    #[test]
    fn agg_kernel_vs_scalar_consistency() {
        let mut rt = RtEngine::load(None).unwrap();
        let mut rng = Rng::new(3);
        let q = AggregationQuery::new(&rt);
        let text = gen_rows(50_000, q.categories, &mut rng);
        let (sums, cnts, rows) = q.combine_rows(&text, &mut rt);
        // Scalar check.
        let mut esum = vec![0f64; q.categories as usize];
        let mut ecnt = vec![0u64; q.categories as usize];
        let mut erows = 0u64;
        for (_, cat, val) in parse_rows(&text) {
            esum[cat as usize] += val as f64;
            ecnt[cat as usize] += 1;
            erows += 1;
        }
        assert_eq!(rows, erows);
        for i in 0..q.categories as usize {
            assert_eq!(cnts[i] as u64, ecnt[i], "cnt seg {i}");
            let rel = (sums[i] as f64 - esum[i]).abs() / esum[i].max(1.0);
            assert!(rel < 1e-3, "sum seg {i}: {} vs {}", sums[i], esum[i]);
        }
    }

    #[test]
    fn agg_combiner_crushes_intermediate() {
        let mut rt = RtEngine::load(None).unwrap();
        let mut rng = Rng::new(4);
        let q = AggregationQuery::new(&rt);
        let text = gen_rows(100_000, q.categories, &mut rng);
        let plan = PartitionPlan::hash(8);
        let k = q.map_split(&Payload::real(text.clone()), &plan,
                            &SystemConfig::marvel_igfs(), &mut rt, &mut rng);
        let raw = q.map_split(&Payload::real(text), &plan,
                              &SystemConfig::corral_lambda(), &mut rt,
                              &mut rng);
        // Raw > input (Table 1 shape); kernel ≤ S × 12 B.
        assert!(raw.total_bytes() > 90_000);
        assert!(k.total_bytes() <= 1024 * 12);
    }

    #[test]
    fn join_expands_intermediate() {
        let mut rt = RtEngine::load(None).unwrap();
        let mut rng = Rng::new(5);
        let q = JoinQuery::new();
        let text = gen_rows(100_000, q.categories, &mut rng);
        let cfg = SystemConfig::corral_lambda();
        let mo = q.map_split(&Payload::real(text), &PartitionPlan::hash(8),
                             &cfg, &mut rt, &mut rng);
        let factor = mo.total_bytes() as f64 / 100_000.0;
        // Table 1: join intermediate ≈ 4× input.
        assert!(factor > 2.0 && factor < 6.0, "join factor {factor}");
    }

    #[test]
    fn synthetic_matches_real_for_queries() {
        let mut rt = RtEngine::load(None).unwrap();
        let agg = AggregationQuery::new(&rt);
        let cfg = SystemConfig::corral_lambda();
        let bytes = 200_000u64;
        let mut check = |wl: &dyn Workload| {
            let mut rng = Rng::new(6);
            let real_in = wl.generate_input(bytes, true, &mut rng);
            let mut rng2 = Rng::new(6);
            let plan = PartitionPlan::hash(8);
            let real = wl.map_split(&real_in, &plan, &cfg, &mut rt,
                                    &mut rng2.fork(0));
            let synth = wl.map_split(&Payload::synthetic(bytes), &plan, &cfg,
                                     &mut rt, &mut rng2);
            let (r, s) =
                (real.total_bytes() as f64, synth.total_bytes() as f64);
            assert!((r - s).abs() / r < 0.15,
                    "{}: real {r} synth {s}", wl.name());
        };
        check(&ScanQuery::new());
        check(&agg);
        check(&JoinQuery::new());
    }
}
