//! Star-schema tables with Zipfian key skew, and the analytical
//! workloads over them: a repartition join (fact ⋈ dimension) and a
//! two-phase group-by — the join-order-benchmark-shaped suite the
//! ROADMAP's "multi-stage joins with skew" item calls for.
//!
//! The input is one byte-reproducible table stream: fixed 36-byte rows,
//! every `interleave`-th row a dimension row (`D,key,attr,pad`), the
//! rest fact rows (`F,key,val,pad`) whose keys are Zipf-sampled — a
//! handful of viral keys carry most of the traffic. Dimension
//! attributes are a pure function of the key, so replicated or
//! duplicated dimension rows are harmless, and the dim stream cycles
//! the key space so every split sees the full dimension table shape.
//!
//! Skew handling: both workloads declare an analytic
//! [`Workload::key_profile`] (the Zipf pmf) so a `SkewAware` plan
//! detects hot keys before any data moves. The join splits hot fact
//! keys across reducers and replicates the matching dim rows to every
//! way ([`SplitMode::Independent`] — joined rows need no merge); the
//! group-by ships one partial row per input row, spreads hot keys, and
//! hands a [`Workload::unifier`] (the merge form of itself) to
//! `JobPipeline`, which appends the re-unifying stage
//! ([`SplitMode::Mergeable`]).

use std::collections::BTreeMap;

use crate::mapreduce::{
    record_salt, MapOutput, PartitionPlan, ReduceOutput, SplitMode,
    SystemConfig, Workload,
};
use crate::runtime::RtEngine;
use crate::storage::Payload;
use crate::util::rng::{Rng, Zipf};

/// Fixed generated table-row length: `T,kkkkkkkk,vvvvvv,` + 17 pad +
/// `\n` (tag 1, key 8, val 6, commas 3, pad 17, newline 1).
pub const TABLE_ROW: u64 = 36;
/// Joined-row length: `kkkkkkkk,vvvvvv,aaaaaa\n`.
pub const JOINED_ROW: u64 = 23;
/// Partial/group row length: `kkkkkkkk,ssssssssssss,ccccccc\n`.
pub const GROUP_ROW: u64 = 30;
/// Fact values are drawn below this (5 digits in a 6-wide field).
pub const FACT_VAL_MAX: u64 = 100_000;

/// Shape of the synthetic star schema: how many distinct join keys the
/// dimension table has, how skewed the fact side's key draw is, and
/// how often a dimension row is interleaved into the stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StarSchema {
    /// Distinct join keys (dimension-table cardinality).
    pub dim_keys: u64,
    /// Zipf exponent of the fact-side key draw; `0.0` = uniform.
    pub zipf_s: f64,
    /// Every `interleave`-th row is a dimension row (position-based,
    /// so split accounting is independent of split boundaries).
    pub interleave: u64,
}

impl StarSchema {
    pub fn new(dim_keys: u64, zipf_s: f64) -> StarSchema {
        StarSchema { dim_keys: dim_keys.max(1), zipf_s, interleave: 8 }
    }

    /// Dimension attribute of `key` — a pure function, so duplicate or
    /// replicated dim rows always agree.
    pub fn attr_of(key: u64) -> u64 {
        crate::util::hash::fnv1a64(&key.to_le_bytes()) % 1_000_000
    }

    /// The fact-key sampler; `None` means uniform (`zipf_s == 0`).
    /// Exponents at the Zipf sampler's s=1 singularity are nudged off
    /// it rather than rejected.
    fn sampler(&self) -> Option<Zipf> {
        if self.zipf_s <= 0.0 {
            return None;
        }
        let s = if (self.zipf_s - 1.0).abs() <= 1e-9 {
            1.0 + 1e-6
        } else {
            self.zipf_s
        };
        Some(Zipf::new(self.dim_keys, s))
    }

    fn draw_fact_key(&self, z: &Option<Zipf>, rng: &mut Rng) -> u64 {
        match z {
            Some(z) => z.sample(rng),
            None => rng.below(self.dim_keys),
        }
    }

    /// Analytic fact-key pmf (the sampler's model): `p[k] ∝ 1/(k+1)^s`,
    /// uniform at `s == 0`. This is what the skew planner sees.
    pub fn key_probs(&self) -> Vec<f64> {
        let n = self.dim_keys as usize;
        if self.zipf_s <= 0.0 {
            return vec![1.0 / n as f64; n];
        }
        let mut p: Vec<f64> = (0..n)
            .map(|k| 1.0 / ((k + 1) as f64).powf(self.zipf_s))
            .collect();
        let h: f64 = p.iter().sum();
        for x in &mut p {
            *x /= h;
        }
        p
    }

    /// Scale the pmf to integer profile weights for the planner.
    fn profile(&self) -> Vec<(u64, u64)> {
        self.key_probs()
            .iter()
            .enumerate()
            .map(|(k, p)| (k as u64, (p * 1e12).round() as u64))
            .collect()
    }

    /// Generate exactly `bytes` of interleaved table rows (tail padded
    /// with spaces past the last whole row, like `queries::gen_rows`).
    pub fn gen_table(&self, bytes: u64, rng: &mut Rng) -> Vec<u8> {
        let z = self.sampler();
        let mut out = Vec::with_capacity(bytes as usize + 64);
        let mut r = 0u64;
        while (out.len() as u64) < bytes {
            if r % self.interleave == 0 {
                let key = (r / self.interleave) % self.dim_keys;
                let attr = Self::attr_of(key);
                push_table_row(&mut out, b'D', key, attr);
            } else {
                let key = self.draw_fact_key(&z, rng);
                let val = rng.below(FACT_VAL_MAX);
                push_table_row(&mut out, b'F', key, val);
            }
            r += 1;
        }
        out.truncate(bytes as usize);
        if let Some(p) = out.iter().rposition(|b| *b == b'\n') {
            out.truncate(p + 1);
            let missing = bytes as usize - out.len();
            out.extend(std::iter::repeat(b' ').take(missing));
        }
        out
    }

    /// Expected (dim, fact) row counts in `rows` interleaved rows.
    fn dim_fact_rows(&self, rows: u64) -> (u64, u64) {
        let dim = rows.div_ceil(self.interleave);
        (dim, rows - dim)
    }
}

impl Default for StarSchema {
    fn default() -> Self {
        StarSchema::new(1024, 1.2)
    }
}

fn push_table_row(out: &mut Vec<u8>, tag: u8, key: u64, val: u64) {
    const PAD: &str = "qrstuvwxyzabcdefg"; // 17 bytes
    out.push(tag);
    out.extend_from_slice(
        format!(",{key:08},{val:06},{PAD}\n").as_bytes(),
    );
}

/// Parse one 35-byte table line (sans newline): `(tag, key, val)`.
fn parse_table_line(line: &[u8]) -> Option<(u8, u64, u64)> {
    if line.len() != TABLE_ROW as usize - 1 {
        return None;
    }
    let tag = line[0];
    if tag != b'F' && tag != b'D' {
        return None;
    }
    let key = parse_u64(&line[2..10])?;
    let val = parse_u64(&line[11..17])?;
    Some((tag, key, val))
}

fn parse_u64(digits: &[u8]) -> Option<u64> {
    std::str::from_utf8(digits).ok()?.parse().ok()
}

fn push_joined_row(out: &mut Vec<u8>, key: u64, val: u64, attr: u64) {
    out.extend_from_slice(
        format!("{key:08},{val:06},{attr:06}\n").as_bytes(),
    );
}

fn group_row_string(key: u64, sum: u64, cnt: u64) -> String {
    // Clamp so the fixed widths can never widen (reachable only far
    // beyond the real-mode materialization cap).
    let sum = sum.min(999_999_999_999);
    let cnt = cnt.min(9_999_999);
    format!("{key:08},{sum:012},{cnt:07}\n")
}

/// Parse a joined (22-byte) or partial (29-byte) line into
/// `(key, sum, cnt)`; other line lengths (padding fragments) skip.
fn parse_group_line(line: &[u8]) -> Option<(u64, u64, u64)> {
    match line.len() {
        l if l == JOINED_ROW as usize - 1 => {
            let key = parse_u64(&line[0..8])?;
            let val = parse_u64(&line[9..15])?;
            Some((key, val, 1))
        }
        l if l == GROUP_ROW as usize - 1 => {
            let key = parse_u64(&line[0..8])?;
            let sum = parse_u64(&line[9..21])?;
            let cnt = parse_u64(&line[22..29])?;
            Some((key, sum, cnt))
        }
        _ => None,
    }
}

// ---------------------------------------------------------------------
// Repartition join: facts route (salted when hot), dims replicate.
// ---------------------------------------------------------------------

/// Fact ⋈ dimension repartition join over a [`StarSchema`] stream.
/// Output: sorted joined rows `key,val,attr`. Hot fact keys may be
/// split across reducers ([`SplitMode::Independent`]): each split way
/// receives a full replica of the hot key's dimension row, so every
/// fact joins wherever it lands and no merge stage is needed.
pub struct RepartitionJoin {
    pub schema: StarSchema,
}

impl RepartitionJoin {
    pub fn new(schema: StarSchema) -> RepartitionJoin {
        RepartitionJoin { schema }
    }

    /// Fraction of fact/dim row mass this plan routes into `part`
    /// (per-byte shares; shared by the synthetic map and reduce).
    fn part_shares(&self, plan: &PartitionPlan, part: usize) -> (f64, f64) {
        let probs = self.schema.key_probs();
        let dim_p = 1.0 / self.schema.dim_keys as f64;
        let (mut fact, mut dim) = (0.0, 0.0);
        for (k, pk) in probs.iter().enumerate() {
            let key = k as u64;
            let w = plan.ways(key);
            for i in 0..w {
                if plan.route_way(key, i) == part {
                    // A hot fact key spreads 1/w of its mass per way;
                    // its dim row replicates whole to every way.
                    fact += pk / w as f64;
                    dim += dim_p;
                }
            }
        }
        (fact, dim)
    }
}

impl Workload for RepartitionJoin {
    fn name(&self) -> &str {
        "repartition_join"
    }

    fn generate_input(&self, bytes: u64, materialize: bool, rng: &mut Rng)
        -> Payload
    {
        if materialize {
            Payload::real(self.schema.gen_table(bytes, rng))
        } else {
            Payload::synthetic(bytes)
        }
    }

    fn map_split(
        &self,
        split: &Payload,
        plan: &PartitionPlan,
        _cfg: &SystemConfig,
        _rt: &mut RtEngine,
        _rng: &mut Rng,
    ) -> MapOutput {
        let parts = plan.parts();
        match split.contiguous() {
            Some(text) => {
                let mut parts_bytes: Vec<Vec<u8>> = vec![Vec::new(); parts];
                let mut records = 0u64;
                for line in text.split(|b| *b == b'\n') {
                    let Some((tag, key, val)) = parse_table_line(line)
                    else {
                        continue;
                    };
                    records += 1;
                    if tag == b'F' {
                        // Content-salted: the same fact row routes to
                        // the same way regardless of split boundaries,
                        // worker count, or replay after a fault.
                        let j = plan.route_salted(key, record_salt(line));
                        parts_bytes[j].extend_from_slice(line);
                        parts_bytes[j].push(b'\n');
                    } else {
                        // Dim rows replicate to every way of their key.
                        for i in 0..plan.ways(key) {
                            let j = plan.route_way(key, i);
                            parts_bytes[j].extend_from_slice(line);
                            parts_bytes[j].push(b'\n');
                        }
                    }
                }
                MapOutput {
                    partitions: parts_bytes
                        .into_iter()
                        .map(Payload::real)
                        .collect(),
                    records,
                }
            }
            None => {
                let rows = split.len() / TABLE_ROW;
                let (dim_rows, fact_rows) = self.schema.dim_fact_rows(rows);
                let partitions = (0..parts)
                    .map(|j| {
                        let (fs, ds) = self.part_shares(plan, j);
                        let b = (fact_rows as f64 * fs
                            + dim_rows as f64 * ds)
                            * TABLE_ROW as f64;
                        Payload::synthetic(b.round() as u64)
                    })
                    .collect();
                MapOutput { partitions, records: rows }
            }
        }
    }

    fn reduce_partition(
        &self,
        part: usize,
        parts: usize,
        inputs: &[Payload],
        cfg: &SystemConfig,
        _rt: &mut RtEngine,
    ) -> ReduceOutput {
        if inputs.iter().all(|p| p.is_real()) {
            // Hash join: dim build side (deduped — attrs are a pure
            // function of the key), fact probe side, sorted output.
            let mut dims = BTreeMap::<u64, u64>::new();
            let mut facts: Vec<(u64, u64)> = Vec::new();
            for p in inputs {
                let Some(text) = p.gather() else { continue };
                for line in text.split(|b| *b == b'\n') {
                    let Some((tag, key, val)) = parse_table_line(line)
                    else {
                        continue;
                    };
                    if tag == b'D' {
                        dims.insert(key, val);
                    } else {
                        facts.push((key, val));
                    }
                }
            }
            facts.sort_unstable();
            let mut out = Vec::with_capacity(
                facts.len() * JOINED_ROW as usize,
            );
            let mut records = 0u64;
            for (key, val) in facts {
                if let Some(attr) = dims.get(&key) {
                    push_joined_row(&mut out, key, val, *attr);
                    records += 1;
                }
            }
            ReduceOutput { output: Payload::real(out), records }
        } else {
            // Synthetic: rebuild the (scale-free) plan from config and
            // invert the per-partition byte shares to joined rows.
            let plan =
                PartitionPlan::build(&cfg.partition, self, 0, parts, 0);
            let (fs, ds) = self.part_shares(&plan, part);
            let in_rows: f64 = inputs
                .iter()
                .map(|p| (p.len() / TABLE_ROW) as f64)
                .sum();
            // in_rows = F·fs + D·ds with F = (interleave−1)·D.
            let il = self.schema.interleave as f64;
            let denom = (il - 1.0) * fs + ds;
            let joined = if denom > 0.0 {
                in_rows / denom * (il - 1.0) * fs
            } else {
                0.0
            };
            ReduceOutput {
                output: Payload::synthetic(
                    (joined * JOINED_ROW as f64).round() as u64,
                ),
                records: joined.round() as u64,
            }
        }
    }

    fn map_rate(&self) -> f64 {
        40e6
    }
    fn reduce_rate(&self) -> f64 {
        60e6
    }

    fn key_profile(&self, _input_bytes: u64, _seed: u64) -> Vec<(u64, u64)> {
        self.schema.profile()
    }
    fn key_domain(&self) -> u64 {
        self.schema.dim_keys
    }
    fn split_mode(&self) -> SplitMode {
        SplitMode::Independent
    }
}

// ---------------------------------------------------------------------
// Group-by: two-phase SUM/COUNT per key with a merge unifier.
// ---------------------------------------------------------------------

/// `SELECT key, SUM(val), COUNT(*) GROUP BY key` over joined rows.
/// The map phase ships one 30-byte partial row per input row (salted
/// routing spreads hot keys); reducers merge into one row per key. A
/// skew-split run leaves a hot key's partials on several reducers —
/// the [`Workload::unifier`] (the merge form of this same workload)
/// re-unifies them in the pipeline-appended merge stage.
pub struct GroupBy {
    pub schema: StarSchema,
    /// Merge form: consumes partial rows, never splits again.
    merge_form: bool,
    unify: Option<Box<GroupBy>>,
}

impl GroupBy {
    pub fn new(schema: StarSchema) -> GroupBy {
        GroupBy {
            schema,
            merge_form: false,
            unify: Some(Box::new(GroupBy {
                schema,
                merge_form: true,
                unify: None,
            })),
        }
    }

    /// Expected input row length for synthetic accounting.
    fn in_row(&self) -> u64 {
        if self.merge_form {
            GROUP_ROW
        } else {
            JOINED_ROW
        }
    }
}

impl Workload for GroupBy {
    fn name(&self) -> &str {
        if self.merge_form {
            "group_by_merge"
        } else {
            "group_by"
        }
    }

    /// Standalone seeding: joined rows with Zipf keys (the same stream
    /// a `RepartitionJoin` stage would hand off).
    fn generate_input(&self, bytes: u64, materialize: bool, rng: &mut Rng)
        -> Payload
    {
        if !materialize {
            return Payload::synthetic(bytes);
        }
        let z = self.schema.sampler();
        let mut out = Vec::with_capacity(bytes as usize + 32);
        while (out.len() as u64) < bytes {
            let key = self.schema.draw_fact_key(&z, rng);
            let val = rng.below(FACT_VAL_MAX);
            push_joined_row(&mut out, key, val, StarSchema::attr_of(key));
        }
        out.truncate(bytes as usize);
        if let Some(p) = out.iter().rposition(|b| *b == b'\n') {
            out.truncate(p + 1);
            let missing = bytes as usize - out.len();
            out.extend(std::iter::repeat(b' ').take(missing));
        }
        Payload::real(out)
    }

    fn map_split(
        &self,
        split: &Payload,
        plan: &PartitionPlan,
        _cfg: &SystemConfig,
        _rt: &mut RtEngine,
        _rng: &mut Rng,
    ) -> MapOutput {
        let parts = plan.parts();
        match split.contiguous() {
            Some(text) => {
                let mut parts_bytes: Vec<Vec<u8>> = vec![Vec::new(); parts];
                let mut records = 0u64;
                for line in text.split(|b| *b == b'\n') {
                    let Some((key, sum, cnt)) = parse_group_line(line)
                    else {
                        continue;
                    };
                    records += 1;
                    let row = group_row_string(key, sum, cnt);
                    let j =
                        plan.route_salted(key, record_salt(row.as_bytes()));
                    parts_bytes[j].extend_from_slice(row.as_bytes());
                }
                MapOutput {
                    partitions: parts_bytes
                        .into_iter()
                        .map(Payload::real)
                        .collect(),
                    records,
                }
            }
            None => {
                let rows = split.len() / self.in_row();
                let probs = if self.merge_form {
                    // Post-combine partials are ≈ uniform per key.
                    vec![
                        1.0 / self.schema.dim_keys as f64;
                        self.schema.dim_keys as usize
                    ]
                } else {
                    self.schema.key_probs()
                };
                let mut acc = vec![0f64; parts];
                for (k, pk) in probs.iter().enumerate() {
                    let key = k as u64;
                    let w = plan.ways(key);
                    for i in 0..w {
                        acc[plan.route_way(key, i)] += rows as f64 * pk
                            * GROUP_ROW as f64
                            / w as f64;
                    }
                }
                MapOutput {
                    partitions: acc
                        .into_iter()
                        .map(|b| Payload::synthetic(b.round() as u64))
                        .collect(),
                    records: rows,
                }
            }
        }
    }

    fn reduce_partition(
        &self,
        part: usize,
        parts: usize,
        inputs: &[Payload],
        cfg: &SystemConfig,
        _rt: &mut RtEngine,
    ) -> ReduceOutput {
        if inputs.iter().all(|p| p.is_real()) {
            let mut merged = BTreeMap::<u64, (u64, u64)>::new();
            for p in inputs {
                let Some(text) = p.gather() else { continue };
                for line in text.split(|b| *b == b'\n') {
                    let Some((key, sum, cnt)) = parse_group_line(line)
                    else {
                        continue;
                    };
                    let e = merged.entry(key).or_insert((0, 0));
                    e.0 += sum;
                    e.1 += cnt;
                }
            }
            let mut out =
                Vec::with_capacity(merged.len() * GROUP_ROW as usize);
            for (key, (sum, cnt)) in &merged {
                out.extend_from_slice(
                    group_row_string(*key, *sum, *cnt).as_bytes(),
                );
            }
            let records = merged.len() as u64;
            ReduceOutput { output: Payload::real(out), records }
        } else {
            // Synthetic: one merged row per key whose spread covers
            // this partition, capped by the rows that arrived.
            let plan =
                PartitionPlan::build(&cfg.partition, self, 0, parts, 0);
            let mut keys = 0u64;
            for k in 0..self.schema.dim_keys {
                let w = plan.ways(k);
                if (0..w).any(|i| plan.route_way(k, i) == part) {
                    keys += 1;
                }
            }
            let in_rows: u64 =
                inputs.iter().map(|p| p.len() / GROUP_ROW).sum();
            let keys = keys.min(in_rows);
            ReduceOutput {
                output: Payload::synthetic(keys * GROUP_ROW),
                records: keys,
            }
        }
    }

    fn map_rate(&self) -> f64 {
        60e6
    }
    fn reduce_rate(&self) -> f64 {
        120e6
    }

    fn key_profile(&self, _input_bytes: u64, _seed: u64) -> Vec<(u64, u64)> {
        if self.merge_form {
            // Merge input is ≈ one row per (key, way): nothing hot.
            Vec::new()
        } else {
            self.schema.profile()
        }
    }
    fn key_domain(&self) -> u64 {
        self.schema.dim_keys
    }
    fn split_mode(&self) -> SplitMode {
        if self.merge_form {
            SplitMode::None
        } else {
            SplitMode::Mergeable
        }
    }
    fn unifier(&self) -> Option<&dyn Workload> {
        self.unify.as_deref().map(|u| u as &dyn Workload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::Partitioner;

    fn cfg() -> SystemConfig {
        SystemConfig::marvel_igfs()
    }

    fn sorted_rows(payloads: &[Payload], row: usize) -> Vec<Vec<u8>> {
        let mut rows: Vec<Vec<u8>> = payloads
            .iter()
            .flat_map(|p| {
                let b = p.gather().unwrap_or_default();
                b.chunks_exact(row)
                    .map(|c| c.to_vec())
                    .collect::<Vec<_>>()
            })
            .collect();
        rows.sort_unstable();
        rows
    }

    /// Run map over `splits` then reduce each partition; returns the
    /// per-partition reduce outputs.
    fn run(
        wl: &dyn Workload,
        splits: &[Payload],
        plan: &PartitionPlan,
        rt: &mut RtEngine,
    ) -> Vec<Payload> {
        let parts = plan.parts();
        let mos: Vec<MapOutput> = splits
            .iter()
            .map(|s| {
                wl.map_split(s, plan, &cfg(), rt, &mut Rng::new(9))
            })
            .collect();
        (0..parts)
            .map(|j| {
                let ins: Vec<Payload> = mos
                    .iter()
                    .map(|m| m.partitions[j].clone())
                    .collect();
                wl.reduce_partition(j, parts, &ins, &cfg(), rt).output
            })
            .collect()
    }

    #[test]
    fn table_generates_exact_bytes_and_parses() {
        let schema = StarSchema::new(64, 1.2);
        let mut rng = Rng::new(1);
        let t = schema.gen_table(10 * TABLE_ROW + 7, &mut rng);
        assert_eq!(t.len() as u64, 10 * TABLE_ROW + 7);
        let mut dims = 0;
        let mut facts = 0;
        for line in t.split(|b| *b == b'\n') {
            if let Some((tag, key, val)) = parse_table_line(line) {
                assert!(key < 64);
                if tag == b'D' {
                    assert_eq!(val, StarSchema::attr_of(key));
                    dims += 1;
                } else {
                    assert!(val < FACT_VAL_MAX);
                    facts += 1;
                }
            }
        }
        assert!(dims >= 1 && facts >= 7, "dims {dims} facts {facts}");
        // Byte-reproducible per seed.
        assert_eq!(t, schema.gen_table(10 * TABLE_ROW + 7, &mut Rng::new(1)));
    }

    #[test]
    fn zipf_profile_flags_hot_keys_at_plan_time() {
        let join = RepartitionJoin::new(StarSchema::new(1024, 1.5));
        let p = Partitioner::SkewAware { hot_threshold: 1.2, split_ways: 4 };
        let plan = PartitionPlan::build(&p, &join, 0, 8, 0);
        assert!(plan.hot_keys_split() >= 1, "s=1.5 must flag hot keys");
        assert!(plan.ways(0) > 1, "rank-0 key is the hottest");
        // Uniform (s=0) profile: nothing hot, plan is pure hash.
        let uni = RepartitionJoin::new(StarSchema::new(1024, 0.0));
        let plan0 = PartitionPlan::build(&p, &uni, 0, 8, 0);
        assert_eq!(plan0.hot_keys_split(), 0);
    }

    #[test]
    fn join_canonical_output_identical_hash_vs_skew() {
        let mut rt = RtEngine::load(None).unwrap();
        let schema = StarSchema::new(128, 1.4);
        let join = RepartitionJoin::new(schema);
        let mut rng = Rng::new(5);
        let table = schema.gen_table(80 * TABLE_ROW, &mut rng);
        // Two splits with a deliberately row-unaligned boundary.
        let cut = 37 * TABLE_ROW as usize + 11;
        let splits = vec![
            Payload::real(table[..cut].to_vec()),
            Payload::real(table[cut..].to_vec()),
        ];
        let hash = PartitionPlan::hash(4);
        let skew = PartitionPlan::build(
            &Partitioner::SkewAware { hot_threshold: 1.2, split_ways: 3 },
            &join, 0, 4, 0,
        );
        assert!(skew.hot_keys_split() >= 1);
        let out_h = run(&join, &splits, &hash, &mut rt);
        let out_s = run(&join, &splits, &skew, &mut rt);
        // Canonical (sorted multiset) equality across partitioners.
        assert_eq!(
            sorted_rows(&out_h, JOINED_ROW as usize),
            sorted_rows(&out_s, JOINED_ROW as usize),
        );
        // Dropping a whole row at a split boundary would lose a fact.
        assert!(!sorted_rows(&out_h, JOINED_ROW as usize).is_empty());
    }

    #[test]
    fn join_split_boundaries_do_not_change_routing() {
        // The same table cut at different offsets must produce the
        // same per-partition byte totals under a skew plan (content
        // salting): pin partition-level identity, not just canonical.
        let mut rt = RtEngine::load(None).unwrap();
        let schema = StarSchema::new(128, 1.4);
        let join = RepartitionJoin::new(schema);
        let table = schema.gen_table(60 * TABLE_ROW, &mut Rng::new(7));
        let skew = PartitionPlan::build(
            &Partitioner::SkewAware { hot_threshold: 1.2, split_ways: 3 },
            &join, 0, 4, 0,
        );
        let whole = vec![Payload::real(table.clone())];
        let cut = 20 * TABLE_ROW as usize;
        let split = vec![
            Payload::real(table[..cut].to_vec()),
            Payload::real(table[cut..].to_vec()),
        ];
        let tally = |splits: &[Payload]| -> Vec<u64> {
            let mut t = vec![0u64; 4];
            for s in splits {
                let mo = join.map_split(s, &skew, &cfg(), &mut rt,
                                        &mut Rng::new(9));
                for (j, p) in mo.partitions.iter().enumerate() {
                    t[j] += p.len();
                }
            }
            t
        };
        assert_eq!(tally(&whole), tally(&split));
    }

    #[test]
    fn group_by_merge_reunifies_split_keys() {
        let mut rt = RtEngine::load(None).unwrap();
        let schema = StarSchema::new(64, 1.5);
        let gb = GroupBy::new(schema);
        let mut rng = Rng::new(11);
        let input = gb.generate_input(100 * JOINED_ROW, true, &mut rng);
        let cut = 50 * JOINED_ROW as usize;
        let text = input.gather().unwrap();
        let splits = vec![
            Payload::real(text[..cut].to_vec()),
            Payload::real(text[cut..].to_vec()),
        ];
        // Golden: hash, no splitting.
        let hash = PartitionPlan::hash(4);
        let golden = sorted_rows(
            &run(&gb, &splits, &hash, &mut rt),
            GROUP_ROW as usize,
        );
        // Skew: hot keys split; reduce outputs hold PARTIAL rows for
        // them, then the unifier's map+reduce (hash plan, as the
        // pipeline's merge stage runs it) re-unifies.
        let skew = PartitionPlan::build(
            &Partitioner::SkewAware { hot_threshold: 1.2, split_ways: 3 },
            &gb, 0, 4, 0,
        );
        assert!(skew.hot_keys_split() >= 1);
        let partials = run(&gb, &splits, &skew, &mut rt);
        let merge = gb.unifier().expect("group_by has a unifier");
        assert_eq!(merge.name(), "group_by_merge");
        assert!(merge.unifier().is_none(), "merge must not chain");
        let merged = sorted_rows(
            &run(merge, &partials, &hash, &mut rt),
            GROUP_ROW as usize,
        );
        assert_eq!(merged, golden);
        // And the skewed pre-merge output is NOT yet unified (the hot
        // key appears on more than one reducer).
        let pre = sorted_rows(&partials, GROUP_ROW as usize);
        assert!(pre.len() > golden.len(), "hot key must be split");
    }

    #[test]
    fn synthetic_accounting_is_deterministic_and_mass_preserving() {
        let mut rt = RtEngine::load(None).unwrap();
        let schema = StarSchema::new(256, 1.3);
        let join = RepartitionJoin::new(schema);
        let plan = PartitionPlan::build(
            &Partitioner::SkewAware { hot_threshold: 1.2, split_ways: 4 },
            &join, 0, 8, 0,
        );
        let a = join.map_split(&Payload::synthetic(1 << 20), &plan, &cfg(),
                               &mut rt, &mut Rng::new(1));
        let b = join.map_split(&Payload::synthetic(1 << 20), &plan, &cfg(),
                               &mut rt, &mut Rng::new(2));
        assert_eq!(a.total_bytes(), b.total_bytes());
        // Total synthetic intermediate ≥ input (dim replication) and
        // within 2× (replication is bounded by split_ways on dims).
        let total = a.total_bytes() as f64;
        assert!(total >= 0.95 * (1 << 20) as f64, "lost mass: {total}");
        assert!(total <= 2.0 * (1 << 20) as f64, "over-replicated");
        let ro = join.reduce_partition(0, 8, &a.partitions, &cfg(), &mut rt);
        assert!(!ro.output.is_real());
        assert!(ro.output.len() > 0);
    }

    #[test]
    fn real_vs_synthetic_map_consistency() {
        let mut rt = RtEngine::load(None).unwrap();
        let schema = StarSchema::new(128, 1.2);
        let join = RepartitionJoin::new(schema);
        let plan = PartitionPlan::hash(8);
        let bytes = 200_000u64;
        let real_in = join.generate_input(bytes, true, &mut Rng::new(3));
        let real = join.map_split(&real_in, &plan, &cfg(), &mut rt,
                                  &mut Rng::new(4));
        let synth = join.map_split(&Payload::synthetic(bytes), &plan,
                                   &cfg(), &mut rt, &mut Rng::new(4));
        let (r, s) = (real.total_bytes() as f64, synth.total_bytes() as f64);
        assert!((r - s).abs() / r < 0.15, "real {r} synth {s}");
        let rel = (real.records as f64 - synth.records as f64).abs()
            / real.records as f64;
        assert!(rel < 0.05, "records diverge {rel}");
    }
}
