//! Property tests over coordinator/substrate invariants (in-repo mini
//! framework — see `util::prop`).

use marvel::hdfs::Hdfs;
use marvel::igfs::{CacheNode, PartitionMap};
use marvel::net::{DeviceRole, NodeId, TopologyBuilder};
use marvel::prop_assert;
use marvel::runtime::{oracle, CombineScheme};
use marvel::sim::{Engine, SimNs, Stage};
use marvel::storage::Payload;
use marvel::util::prop::check;
use marvel::workloads::wordcount::fold_parts;
use marvel::yarn::{ContainerRequest, NodeCapacity, ResourceManager};

fn scheme() -> CombineScheme {
    CombineScheme { parts: 32, buckets: 1024, part_shift: 10 }
}

#[test]
fn prop_partitioner_total_and_stability() {
    // Same key → same partition; all partitions within range; folding
    // onto fewer reducers conserves mass.
    check("partitioner", 100, |g| {
        let s = scheme();
        let n = g.usize_up_to(500) + 1;
        let hashes: Vec<i32> = (0..n)
            .map(|_| (g.rng.next_u32() & 0x7fffffff) as i32)
            .collect();
        let mask = vec![1f32; n];
        let counts = oracle::wordcount_combine(&s, &hashes, &mask);
        let total: f32 = counts.iter().sum();
        prop_assert!((total - n as f32).abs() < 1e-2,
                     "mass {total} != {n}");
        let parts = g.usize_up_to(31) + 1;
        let per_part: Vec<f32> = (0..s.parts)
            .map(|p| counts[p * s.buckets..(p + 1) * s.buckets]
                 .iter().sum::<f32>())
            .collect();
        let folded = fold_parts(&per_part, parts);
        let fsum: f32 = folded.iter().sum();
        prop_assert!((fsum - total).abs() < 1e-2, "fold lost mass");
        for h in &hashes {
            prop_assert!(s.part(*h) < s.parts);
            prop_assert!(s.bucket(*h) < s.buckets);
        }
        Ok(())
    });
}

#[test]
fn prop_hdfs_replicas_distinct_and_data_preserved() {
    check("hdfs-replicas", 60, |g| {
        let nodes = g.usize_up_to(6) + 2;
        let replication = g.usize_up_to(4) + 1;
        let mut engine = Engine::new();
        let topo = TopologyBuilder { nodes, ..Default::default() }
            .build(&mut engine);
        let mut h = Hdfs::new(&topo, DeviceRole::Pmem, replication);
        h.block_size = (g.u64_up_to(200) + 16).max(16);
        let data = g.bytes(2000);
        let writer = NodeId(g.usize_up_to(nodes - 1));
        h.put(&topo, writer, "/f", Payload::real(data.clone()), 0)
            .map_err(|e| e)?;
        // Every block: replicas distinct, count = min(rep, nodes).
        for (meta, reps) in h.block_locations("/f") {
            let mut d = reps.clone();
            d.sort();
            d.dedup();
            prop_assert!(d.len() == reps.len(), "dup replicas");
            prop_assert!(reps.len() == replication.min(nodes),
                         "rep count {} vs {}", reps.len(),
                         replication.min(nodes));
            prop_assert!(meta.len <= h.block_size);
        }
        // Read back from every node: bytes identical (reads are
        // chunked zero-copy views; gather materializes for comparison).
        for r in 0..nodes {
            let (got, _, _, _) =
                h.read(&topo, NodeId(r), "/f", 0).map_err(|e| e)?;
            prop_assert!(got.gather() == Some(data.clone()), "corrupt read");
        }
        Ok(())
    });
}

#[test]
fn prop_cache_capacity_and_no_loss() {
    check("cache-capacity", 80, |g| {
        let cap = g.u64_up_to(1000) + 50;
        let mut c = CacheNode::new(cap);
        let n = g.usize_up_to(60) + 1;
        let mut keys = Vec::new();
        for i in 0..n {
            let len = g.u64_up_to(300);
            let key = format!("k{i}");
            c.put(&key, Payload::synthetic(len));
            keys.push((key, len));
            prop_assert!(c.used() <= cap, "cap exceeded: {} > {cap}",
                         c.used());
        }
        // Nothing is lost: every key readable from DRAM or backing.
        for (k, len) in &keys {
            let (v, _) = c.get(k).ok_or(format!("lost key {k}"))?;
            prop_assert!(v.len() == *len, "len changed");
        }
        Ok(())
    });
}

#[test]
fn prop_rendezvous_minimal_disruption() {
    check("rendezvous", 40, |g| {
        let n = g.usize_up_to(8) + 2;
        let map = PartitionMap::new((0..n).map(NodeId).collect());
        let mut smaller = map.clone();
        let removed = NodeId(g.usize_up_to(n - 1));
        prop_assert!(
            smaller.remove(removed) == Ok(true),
            "member removal must succeed with {n} members"
        );
        for i in 0..200 {
            let k = format!("key-{i}-{}", g.rng.next_u32());
            let before = map.owner(&k);
            let after = smaller.owner(&k);
            if before != removed {
                prop_assert!(before == after,
                             "non-removed key moved: {k}");
            } else {
                prop_assert!(after != removed, "key still on removed node");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scheduler_never_overcommits() {
    check("scheduler", 60, |g| {
        let nodes = g.usize_up_to(6) + 1;
        let vcores = (g.usize_up_to(8) + 1) as u32;
        let caps: Vec<NodeCapacity> = (0..nodes)
            .map(|i| NodeCapacity {
                node: NodeId(i),
                vcores,
                memory_mb: 8192,
            })
            .collect();
        let mut rm = ResourceManager::new(caps);
        let n_reqs = g.usize_up_to(80) + 1;
        let reqs: Vec<ContainerRequest> = (0..n_reqs)
            .map(|_| ContainerRequest {
                vcores: 1,
                memory_mb: 512,
                locality: if g.rng.chance(0.5) {
                    vec![NodeId(g.usize_up_to(nodes - 1))]
                } else {
                    vec![]
                },
            })
            .collect();
        let allocs = rm.allocate(&reqs);
        prop_assert!(allocs.len() == n_reqs, "dropped requests");
        let mut used = vec![0u32; nodes];
        for a in &allocs {
            if a.locality != marvel::yarn::LocalityLevel::Queued {
                used[a.node.0] += 1;
            }
        }
        for (i, &u) in used.iter().enumerate() {
            prop_assert!(u <= vcores, "node {i} overcommitted {u}/{vcores}");
        }
        Ok(())
    });
}

#[test]
fn prop_engine_time_monotone_and_conserving() {
    check("engine-flows", 40, |g| {
        let mut e = Engine::new();
        let cap = (g.u64_up_to(1000) + 10) as f64;
        let link = e.add_resource("l", cap);
        let n = g.usize_up_to(30) + 1;
        let mut total_bytes = 0f64;
        for i in 0..n {
            let b = (g.u64_up_to(10_000) + 1) as f64;
            total_bytes += b;
            e.spawn(&format!("f{i}"), vec![
                Stage::Delay(SimNs::from_micros(g.u64_up_to(50))),
                Stage::Flow { bytes: b, path: vec![link], tag: 0, timeout: None },
            ]);
        }
        let end = e.run().map_err(|x| x)?;
        // Makespan ≥ serialized transfer time (capacity bound)...
        let lower = total_bytes / cap;
        prop_assert!(end.as_secs_f64() + 1e-6 >= lower,
                     "finished faster than link capacity allows");
        // ...and every byte is accounted in the flow log.
        let logged: f64 = e.flow_log.iter().map(|f| f.bytes).sum();
        prop_assert!((logged - total_bytes).abs() < 1e-6, "bytes lost");
        for f in &e.flow_log {
            prop_assert!(f.end >= f.start, "negative flow duration");
        }
        Ok(())
    });
}

#[test]
fn prop_speculation_never_changes_output_bytes() {
    // Random straggler seed × speculation on/off × workers ∈ {1,4,8},
    // plus a co-run leg with an armed FailurePlan: backup races,
    // heterogeneous node speeds, and crash recovery may move virtual
    // time and attempt counts, but never a single output byte.
    use marvel::coordinator::ClusterSpec;
    use marvel::mapreduce::{
        output_key, run_job, stage_named_input, Cluster, JobServer,
        SystemConfig,
    };
    use marvel::net::StragglerProfile;
    use marvel::runtime::RtEngine;
    use marvel::workloads::WordCount;

    fn deploy(cfg: &SystemConfig) -> Cluster {
        let mut cluster = ClusterSpec {
            nodes: 4,
            slots_per_node: 8,
            ..Default::default()
        }
        .deploy(cfg);
        cluster.stores.hdfs.block_size = 256 * 1024;
        cluster
    }

    fn outputs(
        cluster: &mut Cluster,
        job: &str,
        n: usize,
    ) -> Vec<Option<Vec<u8>>> {
        (0..n)
            .map(|j| {
                cluster
                    .stores
                    .igfs
                    .get(&cluster.topo, NodeId(0), &output_key(job, j), 0)
                    .and_then(|(p, _)| p.gather())
            })
            .collect()
    }

    check("speculation-bytes", 5, |g| {
        let sseed = g.rng.next_u64();
        let dseed = g.rng.next_u64();
        let workers = *g.pick(&[1usize, 4, 8]);
        let input = 4 * 1024 * 1024u64; // 16 splits at 256 KiB blocks
        let mut rt = RtEngine::load(None)?;
        let wc = WordCount::new(1500, 1.07, &rt);

        let arm = |speculation: bool, crash: bool, w: usize| {
            let mut c = SystemConfig::marvel_igfs();
            c.map_workers = w;
            c.reduce_workers = w;
            c.stragglers = StragglerProfile {
                seed: sseed,
                prob: 0.5,
                slowdown: 4.0,
            };
            c.speculation.enabled = speculation;
            if crash {
                c.failures.crash_prob = 0.5;
                c.failures.max_failures_per_task = 2;
                c.failures.seed = sseed ^ 0xBEEF;
                c.recovery.max_attempts = 3;
                c.recovery.interval_bytes = 64 * 1024;
            }
            c
        };

        let solo = |cfg: &SystemConfig, rt: &mut RtEngine| {
            let mut cluster = deploy(cfg);
            let input_path = stage_named_input(
                &mut cluster, cfg, &wc, input, dseed, "p/in",
            )?;
            let r = run_job(&mut cluster, cfg, &wc, &input_path, rt, dseed);
            if let Some(e) = &r.failed {
                return Err(format!("job failed: {e}"));
            }
            Ok((outputs(&mut cluster, &r.job, r.reduce.tasks), r))
        };

        // Speculation-off baseline under the random straggler draw.
        let (o_off, r_off) = solo(&arm(false, false, 1), &mut rt)?;
        // Speculation on, random worker count: bytes must not move.
        let (o_on, r_on) = solo(&arm(true, false, workers), &mut rt)?;
        prop_assert!(o_on == o_off,
                     "speculation changed bytes (sseed={sseed:#x})");
        prop_assert!(r_on.output_bytes == r_off.output_bytes);
        prop_assert!(r_on.intermediate_bytes == r_off.intermediate_bytes);
        prop_assert!(r_on.spec_backup_wins <= r_on.spec_backups);
        // (Makespan claims live in stragglers_e2e.rs and fig9 under a
        // controlled profile — duplicate backup flows share bandwidth
        // with originals, so "never slower" is not a property of
        // arbitrary draws.)

        // Co-run with an armed FailurePlan: speculation + crash
        // recovery compose; per-tenant bytes still match solo.
        let base = arm(true, true, workers);
        let mut cluster = deploy(&base);
        let in_a = stage_named_input(
            &mut cluster, &base, &wc, input, dseed, "a/in",
        )?;
        let in_b = stage_named_input(
            &mut cluster, &base, &wc, input, dseed, "b/in",
        )?;
        let res = JobServer::new()
            .tenant("a", 3)
            .tenant("b", 1)
            .job("a", &wc, base.clone(), &in_a, dseed)
            .job("b", &wc, base.clone(), &in_b, dseed)
            .run(&mut cluster, &mut rt);
        prop_assert!(res.ok(), "co-run failed: {:?}", res.failed);
        for run in &res.jobs {
            let jr = run.final_stage().ok_or("no stage")?;
            let outs = outputs(&mut cluster, &jr.job, jr.reduce.tasks);
            prop_assert!(
                outs == o_off,
                "tenant {} diverged under speculation+failures \
                 (sseed={sseed:#x})",
                run.tenant
            );
        }
        Ok(())
    });
}

#[test]
fn prop_degraded_mode_never_changes_output_bytes() {
    // Random netfault seed × straggler seed × crash plan, all armed at
    // once: link fault windows, flow-deadline retries, a cache-node
    // blackout degrading gathers down the tiers, heterogeneous node
    // speeds, speculation, and crash recovery may move virtual time and
    // retry counts — but never a single output byte.
    use marvel::coordinator::ClusterSpec;
    use marvel::mapreduce::{
        output_key, run_job, stage_named_input, Cluster, SystemConfig,
    };
    use marvel::net::{NetFaultPlan, StragglerProfile};
    use marvel::runtime::RtEngine;
    use marvel::workloads::WordCount;

    fn deploy(cfg: &SystemConfig) -> Cluster {
        let mut cluster = ClusterSpec {
            nodes: 4,
            slots_per_node: 8,
            ..Default::default()
        }
        .deploy(cfg);
        cluster.stores.hdfs.block_size = 256 * 1024;
        cluster
    }

    fn outputs(
        cluster: &mut Cluster,
        job: &str,
        n: usize,
    ) -> Vec<Option<Vec<u8>>> {
        (0..n)
            .map(|j| {
                cluster
                    .stores
                    .igfs
                    .get(&cluster.topo, NodeId(0), &output_key(job, j), 0)
                    .and_then(|(p, _)| p.gather())
            })
            .collect()
    }

    check("degraded-mode-bytes", 4, |g| {
        let nseed = g.rng.next_u64();
        let sseed = g.rng.next_u64();
        let dseed = g.rng.next_u64();
        let workers = *g.pick(&[1usize, 4, 8]);
        let input = 4 * 1024 * 1024u64; // 16 splits at 256 KiB blocks
        let mut rt = RtEngine::load(None)?;
        let wc = WordCount::new(1500, 1.07, &rt);

        let arm = |faults: bool| {
            let mut c = SystemConfig::marvel_igfs();
            c.map_workers = if faults { workers } else { 1 };
            c.reduce_workers = c.map_workers;
            if faults {
                c.netfaults = NetFaultPlan {
                    seed: nseed,
                    prob: 0.7,
                    slowdown: 8.0,
                    flow_timeout: SimNs::from_millis(250),
                    degraded_tiers: true,
                    lose_cachenodes: vec![1],
                };
                c.stragglers = StragglerProfile {
                    seed: sseed,
                    prob: 0.5,
                    slowdown: 4.0,
                };
                c.speculation.enabled = true;
                c.failures.crash_prob = 0.5;
                c.failures.max_failures_per_task = 2;
                c.failures.seed = sseed ^ 0xF00D;
                c.recovery.max_attempts = 3;
                c.recovery.interval_bytes = 64 * 1024;
                c.recovery.backoff_base = SimNs::from_millis(50);
            }
            c
        };

        let solo = |cfg: &SystemConfig, rt: &mut RtEngine| {
            let mut cluster = deploy(cfg);
            let input_path = stage_named_input(
                &mut cluster, cfg, &wc, input, dseed, "d/in",
            )?;
            let r = run_job(&mut cluster, cfg, &wc, &input_path, rt, dseed);
            if let Some(e) = &r.failed {
                return Err(format!("job failed: {e}"));
            }
            Ok((outputs(&mut cluster, &r.job, r.reduce.tasks), r))
        };

        let (o0, r0) = solo(&arm(false), &mut rt)?;
        let (of, rf) = solo(&arm(true), &mut rt)?;
        prop_assert!(
            of == o0,
            "degraded mode changed bytes (nseed={nseed:#x} \
             sseed={sseed:#x} workers={workers})"
        );
        prop_assert!(rf.output_bytes == r0.output_bytes);
        prop_assert!(rf.degraded_reads > 0,
                     "blackout of node 1 must degrade some gathers");
        // Deadline expiries are transport retries, not attempts: the
        // attempt ledger stays crash + backup accounting only.
        prop_assert!(
            rf.task_attempts
                >= (rf.map.tasks + rf.reduce.tasks) as u64
        );
        Ok(())
    });
}

#[test]
fn prop_placement_never_changes_output_bytes() {
    // Random placement strategy × straggler × netfault × failure seeds
    // × workers ∈ {1,4,8}, in one generator: placement only moves
    // tasks between nodes — flow endpoints, tier hits, and locality
    // counters follow, but output bytes never move. Pins the ISSUE's
    // hard determinism contract: byte-identical under ANY strategy at
    // any worker count, composing with every armed fault plane.
    use marvel::coordinator::ClusterSpec;
    use marvel::mapreduce::{
        output_key, run_job, stage_named_input, Cluster, JobServer,
        PlacementStrategy, SystemConfig,
    };
    use marvel::net::{NetFaultPlan, StragglerProfile};
    use marvel::runtime::RtEngine;
    use marvel::workloads::WordCount;

    fn deploy(cfg: &SystemConfig) -> Cluster {
        let mut cluster = ClusterSpec {
            nodes: 4,
            slots_per_node: 8,
            ..Default::default()
        }
        .deploy(cfg);
        cluster.stores.hdfs.block_size = 256 * 1024;
        cluster
    }

    fn outputs(
        cluster: &mut Cluster,
        job: &str,
        n: usize,
    ) -> Vec<Option<Vec<u8>>> {
        (0..n)
            .map(|j| {
                cluster
                    .stores
                    .igfs
                    .get(&cluster.topo, NodeId(0), &output_key(job, j), 0)
                    .and_then(|(p, _)| p.gather())
            })
            .collect()
    }

    check("placement-bytes", 5, |g| {
        let pseed = g.rng.next_u64();
        let sseed = g.rng.next_u64();
        let nseed = g.rng.next_u64();
        let dseed = g.rng.next_u64();
        let workers = *g.pick(&[1usize, 4, 8]);
        let strategy = *g.pick(&[
            PlacementStrategy::FairOrder,
            PlacementStrategy::Random { seed: pseed },
            PlacementStrategy::RoundRobin,
            PlacementStrategy::HdfsLocal,
            PlacementStrategy::CacheAffinity,
            PlacementStrategy::StragglerAware,
        ]);
        let input = 4 * 1024 * 1024u64; // 16 splits at 256 KiB blocks
        let mut rt = RtEngine::load(None)?;
        let wc = WordCount::new(1500, 1.07, &rt);

        let arm = |s: PlacementStrategy, faults: bool, w: usize| {
            let mut c = SystemConfig::marvel_igfs();
            c.placement = s;
            c.map_workers = w;
            c.reduce_workers = w;
            if faults {
                c.stragglers = StragglerProfile {
                    seed: sseed,
                    prob: 0.5,
                    slowdown: 4.0,
                };
                c.speculation.enabled = true;
                c.netfaults = NetFaultPlan {
                    seed: nseed,
                    prob: 0.5,
                    slowdown: 8.0,
                    flow_timeout: SimNs::from_millis(250),
                    degraded_tiers: true,
                    lose_cachenodes: vec![],
                };
                c.failures.crash_prob = 0.4;
                c.failures.max_failures_per_task = 2;
                c.failures.seed = sseed ^ 0xACE5;
                c.recovery.max_attempts = 3;
                c.recovery.interval_bytes = 64 * 1024;
            }
            c
        };

        let solo = |cfg: &SystemConfig, rt: &mut RtEngine| {
            let mut cluster = deploy(cfg);
            let input_path = stage_named_input(
                &mut cluster, cfg, &wc, input, dseed, "pl/in",
            )?;
            let r = run_job(&mut cluster, cfg, &wc, &input_path, rt, dseed);
            if let Some(e) = &r.failed {
                return Err(format!("job failed: {e}"));
            }
            Ok((outputs(&mut cluster, &r.job, r.reduce.tasks), r))
        };

        // FairOrder, single worker, no faults: the golden bytes.
        let (o0, r0) =
            solo(&arm(PlacementStrategy::FairOrder, false, 1), &mut rt)?;
        // Random strategy at a random worker count with stragglers,
        // netfaults, speculation, AND crash recovery all armed.
        let (os, rs) = solo(&arm(strategy, true, workers), &mut rt)?;
        prop_assert!(
            os == o0,
            "{} changed bytes (pseed={pseed:#x} sseed={sseed:#x} \
             nseed={nseed:#x} workers={workers})",
            strategy.name()
        );
        prop_assert!(rs.output_bytes == r0.output_bytes);
        prop_assert!(rs.intermediate_bytes == r0.intermediate_bytes);
        prop_assert!(
            rs.locality_ratio >= 0.0 && rs.locality_ratio <= 1.0,
            "locality_ratio out of range: {}",
            rs.locality_ratio
        );

        // Co-run leg: two tenants under the drawn strategy still each
        // reproduce the solo golden bytes through the shared scheduler.
        let base = arm(strategy, true, workers);
        let mut cluster = deploy(&base);
        let in_a = stage_named_input(
            &mut cluster, &base, &wc, input, dseed, "a/in",
        )?;
        let in_b = stage_named_input(
            &mut cluster, &base, &wc, input, dseed, "b/in",
        )?;
        let res = JobServer::new()
            .tenant("a", 3)
            .tenant("b", 1)
            .job("a", &wc, base.clone(), &in_a, dseed)
            .job("b", &wc, base.clone(), &in_b, dseed)
            .run(&mut cluster, &mut rt);
        prop_assert!(res.ok(), "co-run failed: {:?}", res.failed);
        for run in &res.jobs {
            let jr = run.final_stage().ok_or("no stage")?;
            let outs = outputs(&mut cluster, &jr.job, jr.reduce.tasks);
            prop_assert!(
                outs == o0,
                "tenant {} diverged under {} (pseed={pseed:#x})",
                run.tenant,
                strategy.name()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_engine_core_worker_sweep_byte_identical() {
    // The DES-core overhaul's end-to-end contract (ISSUE 9): fig7-
    // shaped (two-tenant co-run through the shared JobServer) and
    // fig9-shaped (stragglers + speculative backups armed) jobs stay
    // byte-identical at EVERY worker count in {1, 4, 8} — the sweep is
    // exhaustive per case, not a random draw, because the wheel/arena/
    // incremental-re-rate hot path and the `oracle_shared` worker
    // engines must agree with the single-threaded golden bytes at each
    // pool width, under randomized straggler/data seeds.
    use marvel::coordinator::ClusterSpec;
    use marvel::mapreduce::{
        output_key, run_job, stage_named_input, Cluster, JobServer,
        SystemConfig,
    };
    use marvel::net::StragglerProfile;
    use marvel::runtime::RtEngine;
    use marvel::workloads::WordCount;

    fn deploy(cfg: &SystemConfig) -> Cluster {
        let mut cluster = ClusterSpec {
            nodes: 4,
            slots_per_node: 8,
            ..Default::default()
        }
        .deploy(cfg);
        cluster.stores.hdfs.block_size = 256 * 1024;
        cluster
    }

    fn outputs(
        cluster: &mut Cluster,
        job: &str,
        n: usize,
    ) -> Vec<Option<Vec<u8>>> {
        (0..n)
            .map(|j| {
                cluster
                    .stores
                    .igfs
                    .get(&cluster.topo, NodeId(0), &output_key(job, j), 0)
                    .and_then(|(p, _)| p.gather())
            })
            .collect()
    }

    check("engine-core-worker-sweep", 3, |g| {
        let sseed = g.rng.next_u64();
        let dseed = g.rng.next_u64();
        let input = 4 * 1024 * 1024u64; // 16 splits at 256 KiB blocks
        let mut rt = RtEngine::load(None)?;
        let wc = WordCount::new(1200, 1.07, &rt);

        // fig9 shape: straggler nodes + speculation racing backups.
        let arm = |w: usize| {
            let mut c = SystemConfig::marvel_igfs();
            c.map_workers = w;
            c.reduce_workers = w;
            c.stragglers = StragglerProfile {
                seed: sseed,
                prob: 0.5,
                slowdown: 4.0,
            };
            c.speculation.enabled = true;
            c
        };

        let solo = |cfg: &SystemConfig, rt: &mut RtEngine| {
            let mut cluster = deploy(cfg);
            let input_path = stage_named_input(
                &mut cluster, cfg, &wc, input, dseed, "ws/in",
            )?;
            let r = run_job(&mut cluster, cfg, &wc, &input_path, rt, dseed);
            if let Some(e) = &r.failed {
                return Err(format!("job failed: {e}"));
            }
            Ok((outputs(&mut cluster, &r.job, r.reduce.tasks), r))
        };

        // Golden: one worker. Then the exhaustive sweep.
        let (o1, r1) = solo(&arm(1), &mut rt)?;
        for w in [1usize, 4, 8] {
            let (ow, rw) = solo(&arm(w), &mut rt)?;
            prop_assert!(
                ow == o1,
                "{w} workers changed bytes (sseed={sseed:#x} \
                 dseed={dseed:#x})"
            );
            prop_assert!(rw.output_bytes == r1.output_bytes);
            prop_assert!(rw.job_time == r1.job_time,
                         "virtual time moved with worker count");

            // fig7 shape at the same width: weighted two-tenant co-run
            // through the shared scheduler reproduces the solo bytes.
            let base = arm(w);
            let mut cluster = deploy(&base);
            let in_a = stage_named_input(
                &mut cluster, &base, &wc, input, dseed, "a/in",
            )?;
            let in_b = stage_named_input(
                &mut cluster, &base, &wc, input, dseed, "b/in",
            )?;
            let res = JobServer::new()
                .tenant("a", 3)
                .tenant("b", 1)
                .job("a", &wc, base.clone(), &in_a, dseed)
                .job("b", &wc, base.clone(), &in_b, dseed)
                .run(&mut cluster, &mut rt);
            prop_assert!(res.ok(), "co-run failed: {:?}", res.failed);
            for run in &res.jobs {
                let jr = run.final_stage().ok_or("no stage")?;
                let outs = outputs(&mut cluster, &jr.job, jr.reduce.tasks);
                prop_assert!(
                    outs == o1,
                    "tenant {} diverged at {w} workers (sseed={sseed:#x})",
                    run.tenant
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shuffle_conservation_real_jobs() {
    // Σ map outputs == Σ reduce inputs for real runs with random
    // sizes/vocab — the shuffle loses and invents nothing.
    use marvel::coordinator::{ClusterSpec, Marvel};
    use marvel::mapreduce::SystemConfig;
    use marvel::workloads::WordCount;
    check("shuffle-conservation", 8, |g| {
        let seed = g.rng.next_u64();
        let vocab = g.usize_up_to(3000) + 100;
        let mut m = Marvel::new(ClusterSpec::default(), seed)
            .map_err(|e| e)?;
        let wc = WordCount::new(vocab, 1.07, &m.rt);
        let bytes = (g.u64_up_to(2_000_000) + 100_000).max(100_000);
        let r = m.run(&SystemConfig::marvel_igfs(), &wc, bytes);
        prop_assert!(r.ok(), "job failed: {:?}", r.failed);
        prop_assert!(r.map.bytes_out == r.reduce.bytes_in,
                     "shuffle not conserving: {} vs {}",
                     r.map.bytes_out, r.reduce.bytes_in);
        Ok(())
    });
}

#[test]
fn prop_partition_plan_canonical_invariance() {
    // ISSUE 10's determinism contract in one generator: a random
    // partitioner (hash / range / skew-aware with random hot-threshold
    // and split-ways) × random Zipf skew × straggler/netfault/crash
    // seeds × workers ∈ {1,4,8}, solo and co-run. Two invariants:
    //   1. Every partitioner reproduces the Hash/1-worker/no-fault
    //      golden as a canonical row multiset (partitioning moves rows
    //      between reducers, never changes them).
    //   2. WITHIN a fixed partitioner, per-partition output bytes are
    //      pinned bit-for-bit across worker counts and fault planes.
    use marvel::coordinator::ClusterSpec;
    use marvel::mapreduce::{
        output_key, run_job, stage_named_input, Cluster, JobServer,
        Partitioner, SystemConfig,
    };
    use marvel::net::{NetFaultPlan, StragglerProfile};
    use marvel::runtime::RtEngine;
    use marvel::workloads::tables::JOINED_ROW;
    use marvel::workloads::{RepartitionJoin, StarSchema};

    fn deploy(cfg: &SystemConfig) -> Cluster {
        let mut cluster = ClusterSpec {
            nodes: 4,
            slots_per_node: 8,
            ..Default::default()
        }
        .deploy(cfg);
        cluster.stores.hdfs.block_size = 256 * 1024;
        cluster
    }

    fn outputs(
        cluster: &mut Cluster,
        job: &str,
        n: usize,
    ) -> Vec<Option<Vec<u8>>> {
        (0..n)
            .map(|j| {
                cluster
                    .stores
                    .igfs
                    .get(&cluster.topo, NodeId(0), &output_key(job, j), 0)
                    .and_then(|(p, _)| p.gather())
            })
            .collect()
    }

    /// Sorted multiset of fixed-width rows — the canonical form that
    /// must agree across partitioners.
    fn canon(outs: &[Option<Vec<u8>>]) -> Vec<Vec<u8>> {
        let mut rows: Vec<Vec<u8>> = outs
            .iter()
            .flatten()
            .flat_map(|b| b.chunks(JOINED_ROW as usize))
            .map(|c| c.to_vec())
            .collect();
        rows.sort_unstable();
        rows
    }

    check("partition-plan", 4, |g| {
        let sseed = g.rng.next_u64();
        let nseed = g.rng.next_u64();
        let dseed = g.rng.next_u64();
        let workers = *g.pick(&[1usize, 4, 8]);
        let zipf_s = *g.pick(&[0.8f64, 1.2, 1.5]);
        let dim_keys = (64 + g.usize_up_to(192)) as u64;
        let hot_threshold = 1.1 + g.rng.f64() * 0.6;
        let split_ways = *g.pick(&[2usize, 3, 4]);
        let partitioner = match *g.pick(&[0usize, 1, 2]) {
            0 => Partitioner::Hash,
            1 => Partitioner::Range { bounds: Vec::new() },
            _ => Partitioner::SkewAware { hot_threshold, split_ways },
        };
        let input = 2 * 1024 * 1024u64; // 8 splits at 256 KiB blocks
        let mut rt = RtEngine::load(None)?;
        let join = RepartitionJoin::new(StarSchema::new(dim_keys, zipf_s));

        let arm = |p: &Partitioner, faults: bool, w: usize| {
            let mut c = SystemConfig::marvel_igfs();
            c.partition = p.clone();
            c.map_workers = w;
            c.reduce_workers = w;
            if faults {
                c.stragglers = StragglerProfile {
                    seed: sseed,
                    prob: 0.5,
                    slowdown: 4.0,
                };
                c.speculation.enabled = true;
                c.netfaults = NetFaultPlan {
                    seed: nseed,
                    prob: 0.5,
                    slowdown: 8.0,
                    flow_timeout: SimNs::from_millis(250),
                    degraded_tiers: true,
                    lose_cachenodes: vec![],
                };
                c.failures.crash_prob = 0.4;
                c.failures.max_failures_per_task = 2;
                c.failures.seed = sseed ^ 0xACE5;
                c.recovery.max_attempts = 3;
                c.recovery.interval_bytes = 64 * 1024;
            }
            c
        };

        let solo = |cfg: &SystemConfig, rt: &mut RtEngine| {
            let mut cluster = deploy(cfg);
            let input_path = stage_named_input(
                &mut cluster, cfg, &join, input, dseed, "pp/in",
            )?;
            let r = run_job(&mut cluster, cfg, &join, &input_path, rt, dseed);
            if let Some(e) = &r.failed {
                return Err(format!("job failed: {e}"));
            }
            Ok((outputs(&mut cluster, &r.job, r.reduce.tasks), r))
        };

        // Hash, single worker, no faults: the canonical golden.
        let (o0, r0) =
            solo(&arm(&Partitioner::Hash, false, 1), &mut rt)?;
        let c0 = canon(&o0);
        prop_assert!(!c0.is_empty(), "golden join produced no rows");

        // The drawn partitioner, quiet: canonically identical rows,
        // identical total bytes — only their placement may move.
        let (ob, rb) = solo(&arm(&partitioner, false, 1), &mut rt)?;
        prop_assert!(
            canon(&ob) == c0,
            "{} changed the row multiset (s={zipf_s} keys={dim_keys})",
            partitioner.name()
        );
        prop_assert!(rb.output_bytes == r0.output_bytes);
        prop_assert!(
            rb.partition_skew >= 1.0 && rb.partition_skew.is_finite(),
            "partition_skew out of range: {}",
            rb.partition_skew
        );

        // Same partitioner with stragglers, netfaults, speculation and
        // crash recovery armed at a random worker count: per-partition
        // bytes are pinned bit-for-bit against the quiet run.
        let (os, rs) = solo(&arm(&partitioner, true, workers), &mut rt)?;
        prop_assert!(
            os == ob,
            "{} moved bytes under faults (sseed={sseed:#x} \
             nseed={nseed:#x} workers={workers})",
            partitioner.name()
        );
        prop_assert!(rs.output_bytes == rb.output_bytes);
        prop_assert!(rs.hot_keys_split == rb.hot_keys_split,
                     "hot-key census moved with the fault plane");

        // Co-run leg: two tenants under the drawn partitioner still
        // each reproduce the per-partition golden bytes.
        let base = arm(&partitioner, true, workers);
        let mut cluster = deploy(&base);
        let in_a = stage_named_input(
            &mut cluster, &base, &join, input, dseed, "a/in",
        )?;
        let in_b = stage_named_input(
            &mut cluster, &base, &join, input, dseed, "b/in",
        )?;
        let res = JobServer::new()
            .tenant("a", 3)
            .tenant("b", 1)
            .job("a", &join, base.clone(), &in_a, dseed)
            .job("b", &join, base.clone(), &in_b, dseed)
            .run(&mut cluster, &mut rt);
        prop_assert!(res.ok(), "co-run failed: {:?}", res.failed);
        for run in &res.jobs {
            let jr = run.final_stage().ok_or("no stage")?;
            let outs = outputs(&mut cluster, &jr.job, jr.reduce.tasks);
            prop_assert!(
                outs == ob,
                "tenant {} diverged under {} (sseed={sseed:#x})",
                run.tenant,
                partitioner.name()
            );
        }
        Ok(())
    });
}
