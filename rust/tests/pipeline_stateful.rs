//! Multi-stage stateful pipeline, end to end: a wordcount seed stage
//! plus three PageRank rounds chained over the IGFS tiers.
//!
//! Pins the acceptance contract: byte-identical final output at any
//! `reduce_workers` (and `map_workers`) setting, nonzero IGFS DRAM
//! hits for stage-to-stage handoff, checkpoint-based resume from the
//! state store, eviction pressure served from the PMEM backing tier,
//! and the HDFS fallback when a middle stage writes durable output.

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{
    output_key, stage_input, Cluster, JobPipeline, PipelineResult,
    StoreKind, SystemConfig,
};
use marvel::net::NodeId;
use marvel::runtime::RtEngine;
use marvel::util::bytes::{GIB, MIB};
use marvel::workloads::{PageRank, WordCount};

const SEED: u64 = 23;
/// PageRank rounds chained after the wordcount seed stage.
const ROUNDS: usize = 3;

fn stage_cfg(base: &SystemConfig, out: StoreKind) -> SystemConfig {
    let mut c = base.clone();
    c.output_store = out;
    c
}

/// Fetch reducer outputs for a stage job: IGFS first (any tier), then
/// HDFS — mirroring the handoff chain.
fn fetch_outputs(
    cluster: &mut Cluster,
    job: &str,
    n: usize,
) -> Vec<Option<Vec<u8>>> {
    (0..n)
        .map(|j| {
            let key = output_key(job, j);
            if let Some((p, _)) =
                cluster.stores.igfs.get(&cluster.topo, NodeId(0), &key, 0)
            {
                return p.gather();
            }
            cluster
                .stores
                .hdfs
                .read(&cluster.topo, NodeId(0), &key, 0)
                .ok()
                .and_then(|(p, _, _, _)| p.gather())
        })
        .collect()
}

struct Run {
    res: PipelineResult,
    outs: Vec<Option<Vec<u8>>>,
}

/// Deploy a fresh cluster, stage 4 MiB of corpus, run the 1+ROUNDS
/// stage pipeline. Non-final stages write their output to `mid_store`;
/// the final stage always writes to IGFS.
fn run_pipeline(
    map_workers: usize,
    reduce_workers: usize,
    igfs_capacity: u64,
    mid_store: StoreKind,
) -> Run {
    let mut base = SystemConfig::marvel_igfs();
    base.map_workers = map_workers;
    base.reduce_workers = reduce_workers;
    base.igfs_capacity = igfs_capacity;
    let mut cluster = ClusterSpec::default().deploy(&base);
    cluster.stores.hdfs.block_size = 256 * 1024;
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(4000, 1.07, &rt);
    let pr = PageRank::new();
    let input =
        stage_input(&mut cluster, &base, &wc, 4 * MIB, SEED).unwrap();
    let mut pipe = JobPipeline::new("wc-pagerank")
        .stage(&wc, stage_cfg(&base, mid_store));
    for k in 0..ROUNDS {
        let out =
            if k == ROUNDS - 1 { StoreKind::Igfs } else { mid_store };
        pipe = pipe.stage(&pr, stage_cfg(&base, out));
    }
    let res = pipe.run(&mut cluster, &mut rt, SEED, &input);
    assert!(res.ok(), "pipeline failed: {:?}", res.failed);
    assert_eq!(res.stages.len(), 1 + ROUNDS);
    let last = res.stages.last().unwrap();
    let n = last.reduce.tasks.max(32);
    let outs = fetch_outputs(&mut cluster, &last.job, n);
    Run { res, outs }
}

fn total_mass(outs: &[Option<Vec<u8>>]) -> u64 {
    outs.iter()
        .flatten()
        .flat_map(|b| b.chunks_exact(12))
        .map(|r| u64::from_le_bytes(r[4..12].try_into().unwrap()))
        .sum()
}

#[test]
fn pipeline_chains_stages_over_igfs_dram() {
    let r = run_pipeline(0, 0, 64 * GIB, StoreKind::Igfs);
    assert!(r.res.restored.iter().all(|x| !x), "nothing to resume yet");
    assert_eq!(r.res.checkpoints, (1 + ROUNDS) as u64);
    // Stage-to-stage handoff was served from DRAM, never from HDFS.
    assert!(r.res.handoff.dram > 0, "handoff: {:?}", r.res.handoff);
    assert_eq!(r.res.handoff.hdfs, 0);
    // Every chained stage's own JobResult shows IGFS DRAM hits.
    for jr in &r.res.stages[1..] {
        assert!(jr.igfs.hits_dram > 0, "{}: {:?}", jr.job, jr.igfs);
        assert!(jr.handoff.dram > 0, "{}: {:?}", jr.job, jr.handoff);
        assert!(jr.output_bytes > 0, "{}", jr.job);
    }
    // The virtual clock is continuous across stages.
    let staged = r
        .res
        .stages
        .iter()
        .fold(marvel::sim::SimNs::ZERO, |a, s| a + s.job_time);
    assert_eq!(staged, r.res.job_time);
    // Final output is real 12-byte rank rows with nonzero mass.
    assert!(r.outs.iter().any(|o| o.as_ref().is_some_and(|b| !b.is_empty())));
    for b in r.outs.iter().flatten() {
        assert_eq!(b.len() % 12, 0, "final output must be rank rows");
    }
    assert!(total_mass(&r.outs) > 0);
}

#[test]
fn pipeline_output_byte_identical_at_reduce_worker_counts() {
    // The acceptance pin: a ≥3-stage pipeline over IGFS produces
    // byte-identical final output at reduce_workers ∈ {1, 4, 8}.
    let r1 = run_pipeline(1, 1, 64 * GIB, StoreKind::Igfs);
    assert!(r1.res.stages[1].igfs.hits_dram > 0,
            "handoff must hit DRAM");
    for workers in [4usize, 8] {
        let rn = run_pipeline(1, workers, 64 * GIB, StoreKind::Igfs);
        assert_eq!(r1.outs, rn.outs,
                   "final output diverged at reduce_workers={workers}");
        assert_eq!(r1.res.job_time, rn.res.job_time,
                   "virtual time diverged at reduce_workers={workers}");
        for (a, b) in r1.res.stages.iter().zip(&rn.res.stages) {
            assert_eq!(a.output_bytes, b.output_bytes, "{}", a.job);
            assert_eq!(a.intermediate_bytes, b.intermediate_bytes,
                       "{}", a.job);
        }
    }
    // Map-plane parallelism composes with the reduce plane.
    let rm = run_pipeline(8, 8, 64 * GIB, StoreKind::Igfs);
    assert_eq!(r1.outs, rm.outs, "map=8/reduce=8 diverged");
}

#[test]
fn pipeline_resumes_from_checkpointed_state() {
    // One cluster, run the pipeline twice: the second run must restore
    // every stage from the state store without recomputing anything.
    let base = {
        let mut b = SystemConfig::marvel_igfs();
        b.map_workers = 2;
        b.reduce_workers = 2;
        b
    };
    let mut cluster = ClusterSpec::default().deploy(&base);
    cluster.stores.hdfs.block_size = 256 * 1024;
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(4000, 1.07, &rt);
    let pr = PageRank::new();
    let input =
        stage_input(&mut cluster, &base, &wc, 4 * MIB, SEED).unwrap();
    let mut pipe = JobPipeline::new("resume-me")
        .stage(&wc, stage_cfg(&base, StoreKind::Igfs));
    for _ in 0..ROUNDS {
        pipe = pipe.stage(&pr, stage_cfg(&base, StoreKind::Igfs));
    }
    let res1 = pipe.run(&mut cluster, &mut rt, SEED, &input);
    assert!(res1.ok(), "{:?}", res1.failed);
    let last1 = res1.stages.last().unwrap();
    let outs1 = fetch_outputs(&mut cluster, &last1.job, 32);
    let batches_after_first = rt.stats.batches;

    let res2 = pipe.run(&mut cluster, &mut rt, SEED, &input);
    assert!(res2.ok(), "{:?}", res2.failed);
    assert!(res2.restored.iter().all(|x| *x),
            "every stage must restore: {:?}", res2.restored);
    assert_eq!(res2.restores, (1 + ROUNDS) as u64);
    assert_eq!(res2.checkpoints, 0, "no recompute, no new checkpoints");
    assert_eq!(res2.job_time.as_nanos(), 0,
               "resumed stages cost zero virtual time");
    assert_eq!(rt.stats.batches, batches_after_first,
               "resume must not re-run the combine kernel");
    // Outputs unchanged and still resolvable.
    let outs2 = fetch_outputs(&mut cluster, &last1.job, 32);
    assert_eq!(outs1, outs2);
    // Per-stage reports carry the checkpointed output accounting.
    for (a, b) in res1.stages.iter().zip(&res2.stages) {
        assert_eq!(a.output_bytes, b.output_bytes);
    }

    // Extending the pipeline resumes the shared prefix and computes
    // only the new round on top of the cached final stage.
    let extended = {
        let mut p = JobPipeline::new("resume-me")
            .stage(&wc, stage_cfg(&base, StoreKind::Igfs));
        for _ in 0..ROUNDS + 1 {
            p = p.stage(&pr, stage_cfg(&base, StoreKind::Igfs));
        }
        p
    };
    let res3 = extended.run(&mut cluster, &mut rt, SEED, &input);
    assert!(res3.ok(), "{:?}", res3.failed);
    assert_eq!(res3.restored.len(), 2 + ROUNDS);
    assert!(res3.restored[..1 + ROUNDS].iter().all(|x| *x));
    assert!(!res3.restored[1 + ROUNDS]);
    let new_stage = res3.stages.last().unwrap();
    assert!(new_stage.handoff.resolved() > 0,
            "new round reads the cached previous round");
    assert!(new_stage.output_bytes > 0);
}

#[test]
fn pipeline_under_capacity_pressure_spills_to_backing_tier() {
    // Satellite: fill the CacheNode far past capacity mid-pipeline and
    // verify evicted intermediates are served from the PMEM backing
    // tier — with the final output still byte-identical.
    let roomy = run_pipeline(2, 2, 64 * GIB, StoreKind::Igfs);
    let tight = run_pipeline(2, 2, 256 * 1024, StoreKind::Igfs);
    assert!(tight.res.igfs.evictions > 0,
            "256 KiB cache must evict: {:?}", tight.res.igfs);
    assert!(tight.res.igfs.bytes_evicted > 0);
    assert!(tight.res.igfs.hits_backing > 0,
            "evicted entries must be served from backing: {:?}",
            tight.res.igfs);
    assert!(tight.res.igfs.hits_dram > 0, "hot entries still hit DRAM");
    // Under no pressure the same pipeline never touches the backing
    // tier and never evicts.
    assert_eq!(roomy.res.igfs.evictions, 0);
    assert_eq!(roomy.res.igfs.hits_backing, 0);
    // Tiering is invisible in the data: byte-identical final output
    // and per-stage accounting.
    assert_eq!(roomy.outs, tight.outs);
    for (a, b) in roomy.res.stages.iter().zip(&tight.res.stages) {
        assert_eq!(a.output_bytes, b.output_bytes, "{}", a.job);
    }
}

#[test]
fn pipeline_middle_stage_falls_back_to_hdfs_or_s3() {
    // Middle stages writing durable HDFS (or remote S3) output
    // exercise the tail of the DRAM → backing → HDFS → S3 chain.
    let igfs = run_pipeline(2, 2, 64 * GIB, StoreKind::Igfs);
    let hdfs = run_pipeline(2, 2, 64 * GIB, StoreKind::Hdfs);
    assert!(hdfs.res.handoff.hdfs > 0, "{:?}", hdfs.res.handoff);
    assert_eq!(hdfs.res.handoff.dram, 0,
               "mid outputs were never cached in DRAM");
    let s3 = run_pipeline(2, 2, 64 * GIB, StoreKind::S3);
    assert!(s3.res.handoff.s3 > 0, "{:?}", s3.res.handoff);
    assert_eq!(s3.res.handoff.dram + s3.res.handoff.hdfs, 0);
    // The store a stage hands off through cannot change the data.
    assert_eq!(igfs.outs, hdfs.outs);
    assert_eq!(igfs.outs, s3.outs);
    assert_eq!(total_mass(&igfs.outs), total_mass(&hdfs.outs));
}

#[test]
fn pipeline_recomputes_invalidated_stage_without_collision() {
    // Lose one output of a mid stage on a write-once backend (HDFS):
    // the stage's checkpoint must fail validation, the stage must
    // re-execute cleanly (stale keys scrubbed, no 'already exists'),
    // and downstream stages with intact outputs stay restored.
    let base = {
        let mut b = SystemConfig::marvel_igfs();
        b.map_workers = 2;
        b.reduce_workers = 2;
        b
    };
    let mut cluster = ClusterSpec::default().deploy(&base);
    cluster.stores.hdfs.block_size = 256 * 1024;
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(4000, 1.07, &rt);
    let pr = PageRank::new();
    let input =
        stage_input(&mut cluster, &base, &wc, 4 * MIB, SEED).unwrap();
    let mut pipe = JobPipeline::new("redo")
        .stage(&wc, stage_cfg(&base, StoreKind::Hdfs));
    for _ in 0..ROUNDS {
        pipe = pipe.stage(&pr, stage_cfg(&base, StoreKind::Hdfs));
    }
    let res1 = pipe.run(&mut cluster, &mut rt, SEED, &input);
    assert!(res1.ok(), "{:?}", res1.failed);
    let last_job = pipe.stage_job(ROUNDS);
    let outs1 = fetch_outputs(&mut cluster, &last_job, 32);

    // Delete one of stage 1's committed outputs.
    let victim = (0..32)
        .map(|j| output_key(&pipe.stage_job(1), j))
        .find(|k| cluster.stores.hdfs.namenode.stat(k).is_some())
        .expect("stage 1 wrote at least one output");
    assert!(cluster.stores.hdfs.delete(&victim));

    let res2 = pipe.run(&mut cluster, &mut rt, SEED, &input);
    assert!(res2.ok(), "re-run failed: {:?}", res2.failed);
    assert_eq!(res2.restored, vec![true, false, true, true],
               "only the invalidated stage recomputes");
    // Deterministic recompute: the final output is unchanged.
    let outs2 = fetch_outputs(&mut cluster, &last_job, 32);
    assert_eq!(outs1, outs2);
}
