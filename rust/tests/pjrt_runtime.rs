//! PJRT-vs-oracle equivalence: the core cross-layer correctness signal.
//! Requires `make artifacts`; tests self-skip (with a loud message)
//! when the artifacts have not been built.

use marvel::runtime::{default_artifacts_dir, oracle, RtEngine};
use marvel::util::rng::Rng;

fn engines() -> Option<(RtEngine, RtEngine)> {
    if !cfg!(feature = "pjrt") {
        // Built against the xla stub: artifacts load oracle-only, so
        // there is no PJRT side to compare.
        return None;
    }
    let dir = default_artifacts_dir()?;
    let pjrt = RtEngine::load(Some(&dir)).expect("load artifacts");
    assert!(pjrt.is_pjrt());
    let orac = RtEngine::load(None).expect("oracle");
    Some((pjrt, orac))
}

macro_rules! require_artifacts {
    () => {
        match engines() {
            Some(e) => e,
            None => {
                eprintln!("SKIP: needs `--features pjrt` + artifacts/ \
                           (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn wordcount_combine_pjrt_equals_oracle() {
    let (mut pjrt, mut orac) = require_artifacts!();
    let n = pjrt.batch_size();
    for seed in [1u64, 2, 3] {
        let mut rng = Rng::new(seed);
        let hashes: Vec<i32> =
            (0..n).map(|_| (rng.next_u32() & 0x7fffffff) as i32).collect();
        let mask: Vec<f32> =
            (0..n).map(|_| if rng.chance(0.9) { 1.0 } else { 0.0 }).collect();
        let a = pjrt.wordcount_batch(&hashes, &mask).unwrap();
        let b = orac.wordcount_batch(&hashes, &mask).unwrap();
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!((x - y).abs() < 1e-3, "seed {seed} cell {i}: {x} vs {y}");
        }
        let total: f32 = a.iter().sum();
        let live: f32 = mask.iter().sum();
        assert!((total - live).abs() < 1e-2, "mass: {total} vs {live}");
    }
}

#[test]
fn grep_combine_pjrt_equals_oracle() {
    let (mut pjrt, mut orac) = require_artifacts!();
    let n = pjrt.batch_size();
    let w = pjrt.manifest.word_width;
    let mut rng = Rng::new(7);
    let tokens: Vec<i32> =
        (0..n * w).map(|_| (rng.below(4) + 97) as i32).collect();
    let hashes: Vec<i32> =
        (0..n).map(|_| (rng.next_u32() & 0x7fffffff) as i32).collect();
    let mask = vec![1f32; n];
    let mut pattern = vec![oracle::WILD_REST; w];
    pattern[0] = 97; // 1/4 of tokens match on first byte
    let (ca, ta) = pjrt.grep_batch(&tokens, &hashes, &mask, &pattern).unwrap();
    let (cb, tb) = orac.grep_batch(&tokens, &hashes, &mask, &pattern).unwrap();
    assert!((ta - tb).abs() < 1e-3, "totals {ta} vs {tb}");
    assert!(ta > 0.0, "degenerate: no matches");
    for (x, y) in ca.iter().zip(&cb) {
        assert!((x - y).abs() < 1e-3);
    }
}

#[test]
fn agg_combine_pjrt_equals_oracle() {
    let (mut pjrt, mut orac) = require_artifacts!();
    let n = pjrt.manifest.small_batch;
    let s = pjrt.manifest.segments;
    let mut rng = Rng::new(11);
    let ids: Vec<i32> = (0..n).map(|_| rng.below(s as u64) as i32).collect();
    let vals: Vec<f32> = (0..n).map(|_| rng.f64() as f32 * 100.0).collect();
    let mask: Vec<f32> =
        (0..n).map(|_| if rng.chance(0.8) { 1.0 } else { 0.0 }).collect();
    let (sa, ca) = pjrt.agg_batch(&ids, &vals, &mask).unwrap();
    let (sb, cb) = orac.agg_batch(&ids, &vals, &mask).unwrap();
    for i in 0..s {
        assert!((sa[i] - sb[i]).abs() < 0.5, "sum seg {i}: {} vs {}",
                sa[i], sb[i]);
        assert!((ca[i] - cb[i]).abs() < 1e-3, "cnt seg {i}");
    }
}

#[test]
fn manifest_hashes_match_files() {
    let Some(dir) = default_artifacts_dir() else {
        eprintln!("SKIP: artifacts/ not built");
        return;
    };
    let m = marvel::runtime::Manifest::load(&dir).unwrap();
    for (name, meta) in &m.artifacts {
        let text = std::fs::read_to_string(&meta.file).unwrap();
        assert!(text.contains("HloModule"), "{name} not HLO text");
        assert!(!meta.sha256.is_empty(), "{name} missing digest");
    }
}

#[test]
fn pjrt_full_job_equals_oracle_job() {
    // Same seed, same workload — the PJRT-backed job must produce
    // byte-identical data-plane results to the oracle-backed job.
    use marvel::coordinator::{ClusterSpec, Marvel};
    use marvel::mapreduce::SystemConfig;
    use marvel::util::bytes::MIB;
    use marvel::workloads::WordCount;

    if default_artifacts_dir().is_none() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let run = |force_oracle: bool| {
        let mut m = Marvel::new(ClusterSpec::default(), 5).unwrap();
        if force_oracle {
            m.rt = RtEngine::load(None).unwrap();
        }
        let wc = WordCount::new(3000, 1.07, &m.rt);
        let r = m.run(&SystemConfig::marvel_igfs(), &wc, 4 * MIB);
        assert!(r.ok());
        (r.intermediate_bytes, r.output_bytes, r.job_time)
    };
    let (ia, oa, ta) = run(false);
    let (ib, ob, tb) = run(true);
    assert_eq!(ia, ib, "intermediate bytes differ pjrt vs oracle");
    assert_eq!(oa, ob, "output bytes differ");
    assert_eq!(ta, tb, "virtual time differs");
}
