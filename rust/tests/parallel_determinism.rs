//! The parallel map data plane's determinism contract: `run_job` must
//! produce byte-identical results at ANY data-plane worker count —
//! same JobResult accounting, same virtual completion time, and the
//! same output bytes in the output store (see the DESIGN note on
//! `mapreduce::driver::map_splits_parallel`).

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{
    output_key, run_job, stage_input, JobResult, StoreKind, SystemConfig,
};
use marvel::net::NodeId;
use marvel::runtime::RtEngine;
use marvel::util::bytes::MIB;
use marvel::workloads::WordCount;

const SEED: u64 = 11;

/// Run one wordcount job with `workers` map threads over 16 real
/// splits; return the report plus every reducer's output bytes.
fn run_with_workers(
    cfg_base: &SystemConfig,
    workers: usize,
) -> (JobResult, Vec<Option<Vec<u8>>>) {
    let mut cfg = cfg_base.clone();
    cfg.map_workers = workers;
    let mut cluster = ClusterSpec::default().deploy(&cfg);
    // Small blocks → 16 splits from a 4 MiB input, so multiple map
    // tasks genuinely interleave across workers.
    cluster.stores.hdfs.block_size = 256 * 1024;
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(4000, 1.07, &rt);
    let input =
        stage_input(&mut cluster, &cfg, &wc, 4 * MIB, SEED).unwrap();
    let r = run_job(&mut cluster, &cfg, &wc, &input, &mut rt, SEED);
    assert!(r.ok(), "workers={workers}: {:?}", r.failed);
    assert!(r.map.tasks > 1, "need multiple splits to exercise workers");
    let job = wc.name().to_string();
    let outs = (0..r.reduce.tasks)
        .map(|j| {
            let key = output_key(&job, j);
            let p = match cfg.output_store {
                StoreKind::Igfs => cluster
                    .stores
                    .igfs
                    .get(&cluster.topo, NodeId(0), &key, 0)
                    .map(|(p, _)| p),
                StoreKind::Hdfs => cluster
                    .stores
                    .hdfs
                    .read(&cluster.topo, NodeId(0), &key, 0)
                    .ok()
                    .map(|(p, _, _, _)| p),
                StoreKind::S3 => cluster.stores.s3.get(&key),
            };
            p.map(|p| p.gather().expect("real output"))
        })
        .collect();
    (r, outs)
}

#[test]
fn output_byte_identical_for_1_2_and_8_workers() {
    let cfg = SystemConfig::marvel_igfs();
    let (r1, o1) = run_with_workers(&cfg, 1);
    for workers in [2usize, 8] {
        let (rn, on) = run_with_workers(&cfg, workers);
        assert_eq!(r1.intermediate_bytes, rn.intermediate_bytes,
                   "workers={workers}");
        assert_eq!(r1.output_bytes, rn.output_bytes, "workers={workers}");
        assert_eq!(r1.map.bytes_out, rn.map.bytes_out, "workers={workers}");
        assert_eq!(r1.reduce.bytes_in, rn.reduce.bytes_in,
                   "workers={workers}");
        assert_eq!(r1.job_time, rn.job_time,
                   "virtual time must not depend on host threads \
                    (workers={workers})");
        assert_eq!(r1.rt_batches, rn.rt_batches, "workers={workers}");
        assert_eq!(o1.len(), on.len());
        for (j, (a, b)) in o1.iter().zip(&on).enumerate() {
            assert_eq!(a, b,
                       "reducer {j} output diverged at workers={workers}");
        }
    }
    // The outputs are non-trivial: at least one reducer wrote bytes.
    assert!(o1.iter().any(|o| o.as_ref().map_or(false, |b| !b.is_empty())));
}

#[test]
fn auto_worker_count_matches_serial() {
    // map_workers = 0 (auto) must also match the serial baseline.
    let cfg = SystemConfig::marvel_igfs();
    let (r1, o1) = run_with_workers(&cfg, 1);
    let (ra, oa) = run_with_workers(&cfg, 0);
    assert_eq!(r1.output_bytes, ra.output_bytes);
    assert_eq!(r1.job_time, ra.job_time);
    assert_eq!(o1, oa);
}

#[test]
fn raw_path_parallel_determinism() {
    // The Corral-style raw path (no combiner, JSON framing) goes
    // through the borrowed-slice reduce keying — same contract.
    let mut cfg = SystemConfig::marvel_igfs_paper();
    cfg.materialize_cap = 32 * MIB;
    let (r1, o1) = run_with_workers(&cfg, 1);
    let (r4, o4) = run_with_workers(&cfg, 4);
    assert_eq!(r1.intermediate_bytes, r4.intermediate_bytes);
    assert_eq!(r1.output_bytes, r4.output_bytes);
    assert_eq!(o1, o4);
}
