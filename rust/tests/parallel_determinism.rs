//! The parallel data plane's determinism contract: `run_job` must
//! produce byte-identical results at ANY map or reduce worker count —
//! same JobResult accounting, same virtual completion time, and the
//! same output bytes in the output store (see the DESIGN note on
//! `mapreduce::driver::pool_run`).

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{
    output_key, run_job, stage_input, JobResult, StoreKind, SystemConfig,
};
use marvel::net::NodeId;
use marvel::runtime::RtEngine;
use marvel::util::bytes::MIB;
use marvel::workloads::WordCount;

const SEED: u64 = 11;

/// Run one wordcount job with the given data-plane worker counts over
/// 16 real splits; return the report plus every reducer's output bytes.
fn run_with_workers(
    cfg_base: &SystemConfig,
    map_workers: usize,
    reduce_workers: usize,
) -> (JobResult, Vec<Option<Vec<u8>>>) {
    let mut cfg = cfg_base.clone();
    cfg.map_workers = map_workers;
    cfg.reduce_workers = reduce_workers;
    let mut cluster = ClusterSpec::default().deploy(&cfg);
    // Small blocks → 16 splits from a 4 MiB input, so multiple map
    // tasks genuinely interleave across workers.
    cluster.stores.hdfs.block_size = 256 * 1024;
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(4000, 1.07, &rt);
    let input =
        stage_input(&mut cluster, &cfg, &wc, 4 * MIB, SEED).unwrap();
    let r = run_job(&mut cluster, &cfg, &wc, &input, &mut rt, SEED);
    assert!(r.ok(), "workers={map_workers}/{reduce_workers}: {:?}",
            r.failed);
    assert!(r.map.tasks > 1, "need multiple splits to exercise workers");
    let job = wc.name().to_string();
    let outs = (0..r.reduce.tasks)
        .map(|j| {
            let key = output_key(&job, j);
            let p = match cfg.output_store {
                StoreKind::Igfs => cluster
                    .stores
                    .igfs
                    .get(&cluster.topo, NodeId(0), &key, 0)
                    .map(|(p, _)| p),
                StoreKind::Hdfs => cluster
                    .stores
                    .hdfs
                    .read(&cluster.topo, NodeId(0), &key, 0)
                    .ok()
                    .map(|(p, _, _, _)| p),
                StoreKind::S3 => cluster.stores.s3.get(&key),
            };
            p.map(|p| p.gather().expect("real output"))
        })
        .collect();
    (r, outs)
}

fn assert_identical(
    r1: &JobResult,
    o1: &[Option<Vec<u8>>],
    rn: &JobResult,
    on: &[Option<Vec<u8>>],
    label: &str,
) {
    assert_eq!(r1.intermediate_bytes, rn.intermediate_bytes, "{label}");
    assert_eq!(r1.output_bytes, rn.output_bytes, "{label}");
    assert_eq!(r1.map.bytes_out, rn.map.bytes_out, "{label}");
    assert_eq!(r1.reduce.bytes_in, rn.reduce.bytes_in, "{label}");
    assert_eq!(r1.job_time, rn.job_time,
               "virtual time must not depend on host threads ({label})");
    assert_eq!(r1.rt_batches, rn.rt_batches, "{label}");
    assert_eq!(o1.len(), on.len());
    for (j, (a, b)) in o1.iter().zip(on).enumerate() {
        assert_eq!(a, b, "reducer {j} output diverged at {label}");
    }
}

#[test]
fn output_byte_identical_for_1_2_and_8_map_workers() {
    let cfg = SystemConfig::marvel_igfs();
    let (r1, o1) = run_with_workers(&cfg, 1, 1);
    for workers in [2usize, 8] {
        let (rn, on) = run_with_workers(&cfg, workers, 1);
        assert_identical(&r1, &o1, &rn, &on,
                         &format!("map_workers={workers}"));
    }
    // The outputs are non-trivial: at least one reducer wrote bytes.
    assert!(o1.iter().any(|o| o.as_ref().map_or(false, |b| !b.is_empty())));
}

#[test]
fn output_byte_identical_for_1_4_and_8_reduce_workers() {
    let cfg = SystemConfig::marvel_igfs();
    let (r1, o1) = run_with_workers(&cfg, 1, 1);
    for workers in [4usize, 8] {
        let (rn, on) = run_with_workers(&cfg, 1, workers);
        assert_identical(&r1, &o1, &rn, &on,
                         &format!("reduce_workers={workers}"));
    }
    assert!(r1.reduce.tasks > 1, "need multiple partitions");
}

#[test]
fn map_and_reduce_workers_compose() {
    // Sweeping both planes together must still match fully serial.
    let cfg = SystemConfig::marvel_igfs();
    let (r1, o1) = run_with_workers(&cfg, 1, 1);
    let (rn, on) = run_with_workers(&cfg, 8, 8);
    assert_identical(&r1, &o1, &rn, &on, "map=8/reduce=8");
}

#[test]
fn auto_worker_count_matches_serial() {
    // workers = 0 (auto) must also match the serial baseline.
    let cfg = SystemConfig::marvel_igfs();
    let (r1, o1) = run_with_workers(&cfg, 1, 1);
    let (ra, oa) = run_with_workers(&cfg, 0, 0);
    assert_eq!(r1.output_bytes, ra.output_bytes);
    assert_eq!(r1.job_time, ra.job_time);
    assert_eq!(o1, oa);
}

#[test]
fn raw_path_parallel_determinism() {
    // The Corral-style raw path (no combiner, JSON framing) goes
    // through the borrowed-slice reduce keying — same contract.
    let mut cfg = SystemConfig::marvel_igfs_paper();
    cfg.materialize_cap = 32 * MIB;
    let (r1, o1) = run_with_workers(&cfg, 1, 1);
    let (r4, o4) = run_with_workers(&cfg, 4, 4);
    assert_eq!(r1.intermediate_bytes, r4.intermediate_bytes);
    assert_eq!(r1.output_bytes, r4.output_bytes);
    assert_eq!(o1, o4);
}
