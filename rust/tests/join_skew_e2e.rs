//! Star-schema join suite, end to end: a repartition join stage chained
//! into a group-by through the IGFS handoff, under every partitioner.
//!
//! Pins ISSUE 10's acceptance contract: `Partitioner::Hash` reproduces
//! the legacy `key % parts` routing bit-for-bit; `SkewAware` detects
//! and splits hot Zipf keys at plan time (`hot_keys_split > 0` at
//! s ≥ 1.2) and the pipeline appends a merge stage that re-unifies the
//! split partials; canonical outputs are identical across partitioners,
//! worker counts, and armed fault planes; and the per-stage checkpoint
//! covers the merge, so a lost merge output forces exactly that stage
//! (and its merge) to recompute.

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{
    output_key, stage_input, Cluster, JobPipeline, PartitionPlan,
    Partitioner, PipelineResult, SystemConfig,
};
use marvel::net::{NetFaultPlan, NodeId, StragglerProfile};
use marvel::runtime::RtEngine;
use marvel::sim::SimNs;
use marvel::util::bytes::MIB;
use marvel::workloads::tables::GROUP_ROW;
use marvel::workloads::{GroupBy, RepartitionJoin, StarSchema};

const SEED: u64 = 31;
/// Hot enough that the head keys dominate (fig13's skewed regime).
const ZIPF_S: f64 = 1.5;
const DIM_KEYS: u64 = 256;

fn skew() -> Partitioner {
    Partitioner::SkewAware { hot_threshold: 1.3, split_ways: 4 }
}

fn schema() -> StarSchema {
    StarSchema::new(DIM_KEYS, ZIPF_S)
}

fn base_cfg(p: &Partitioner, workers: usize, faults: bool) -> SystemConfig {
    let mut c = SystemConfig::marvel_igfs();
    c.partition = p.clone();
    c.map_workers = workers;
    c.reduce_workers = workers;
    if faults {
        c.stragglers =
            StragglerProfile { seed: 7, prob: 0.5, slowdown: 4.0 };
        c.speculation.enabled = true;
        c.netfaults = NetFaultPlan {
            seed: 11,
            prob: 0.4,
            slowdown: 8.0,
            flow_timeout: SimNs::from_millis(250),
            degraded_tiers: true,
            lose_cachenodes: vec![],
        };
        c.failures.crash_prob = 0.3;
        c.failures.max_failures_per_task = 2;
        c.failures.seed = 13;
        c.recovery.max_attempts = 3;
        c.recovery.interval_bytes = 64 * 1024;
    }
    c
}

fn deploy(cfg: &SystemConfig) -> Cluster {
    let mut cluster = ClusterSpec {
        nodes: 4,
        slots_per_node: 8,
        ..Default::default()
    }
    .deploy(cfg);
    cluster.stores.hdfs.block_size = 256 * 1024;
    cluster
}

fn fetch_outputs(
    cluster: &mut Cluster,
    job: &str,
    n: usize,
) -> Vec<Option<Vec<u8>>> {
    (0..n)
        .map(|j| {
            cluster
                .stores
                .igfs
                .get(&cluster.topo, NodeId(0), &output_key(job, j), 0)
                .and_then(|(p, _)| p.gather())
        })
        .collect()
}

/// Sorted multiset of fixed-width rows: the canonical form that must
/// agree across partitioners (which only move rows between reducers).
fn canon(outs: &[Option<Vec<u8>>], row: usize) -> Vec<Vec<u8>> {
    let mut rows: Vec<Vec<u8>> = outs
        .iter()
        .flatten()
        .flat_map(|b| b.chunks(row))
        .map(|c| c.to_vec())
        .collect();
    rows.sort_unstable();
    rows
}

struct Run {
    res: PipelineResult,
    finals: Vec<Option<Vec<u8>>>,
}

/// Deploy a fresh cluster, stage 4 MiB of fact+dimension tables, run
/// join → group-by (the pipeline appends the merge stage itself when
/// the plan split hot keys).
fn run_suite(cfg: &SystemConfig) -> Run {
    let mut cluster = deploy(cfg);
    let mut rt = RtEngine::load(None).unwrap();
    let join = RepartitionJoin::new(schema());
    let gb = GroupBy::new(schema());
    let input =
        stage_input(&mut cluster, cfg, &join, 4 * MIB, SEED).unwrap();
    let res = JobPipeline::new("starjoin")
        .stage(&join, cfg.clone())
        .stage(&gb, cfg.clone())
        .run(&mut cluster, &mut rt, SEED, &input);
    assert!(res.ok(), "pipeline failed: {:?}", res.failed);
    let fin = res.final_output().expect("no final stage");
    let finals =
        fetch_outputs(&mut cluster, &fin.job, fin.reduce.tasks.max(1));
    Run { res, finals }
}

#[test]
fn hash_partitioner_is_legacy_modulo_routing() {
    // The legacy contract, pinned at the plan level: `Hash` routes
    // every key to `key % parts`, splits nothing, for any plan width.
    let join = RepartitionJoin::new(schema());
    for parts in [1usize, 4, 7, 32] {
        let plan = PartitionPlan::build(
            &Partitioner::Hash, &join, 0, parts, SEED,
        );
        assert_eq!(plan.parts(), parts);
        assert_eq!(plan.hot_keys_split(), 0);
        for k in 0..1000u64 {
            assert_eq!(plan.route(k), (k % parts as u64) as usize);
            assert_eq!(plan.ways(k), 1);
        }
    }
}

#[test]
fn skew_aware_splits_hot_keys_and_matches_hash_canonically() {
    let hash = run_suite(&base_cfg(&Partitioner::Hash, 1, false));
    // Hash: nothing is ever split, no merge stages appended.
    assert!(hash.res.merges.iter().all(|m| m.is_none()));
    for jr in &hash.res.stages {
        assert_eq!(jr.hot_keys_split, 0, "{}", jr.job);
        assert!(jr.partition_skew >= 1.0, "{}", jr.job);
    }
    // At s = 1.5 the head keys dwarf the mean partition: Hash piles
    // them onto single reducers and the byte census shows it.
    assert!(
        hash.res.stages[0].partition_skew > 1.5,
        "skewed input must show partition imbalance under hash: {}",
        hash.res.stages[0].partition_skew
    );

    let sk = run_suite(&base_cfg(&skew(), 1, false));
    // Both stages detect and split the hot keys at plan time…
    assert!(sk.res.stages[0].hot_keys_split > 0, "join split nothing");
    assert!(sk.res.stages[1].hot_keys_split > 0, "group-by split nothing");
    // …but only the group-by owes a merge (join splits are independent
    // rows; group-by partials must be re-unified by its unifier).
    assert!(sk.res.merges[0].is_none(), "join needs no merge");
    let merge = sk.res.merges[1].as_ref().expect("group-by merge missing");
    assert!(merge.output_bytes > 0);
    assert_eq!(merge.output_bytes % GROUP_ROW, 0);
    // Pre-merge outputs are strictly larger: split keys left partial
    // aggregates on several reducers.
    assert!(
        sk.res.stages[1].output_bytes > merge.output_bytes,
        "{} !> {}",
        sk.res.stages[1].output_bytes,
        merge.output_bytes
    );

    // The acceptance pin: canonically identical final rows, identical
    // total bytes — the partitioner moved rows, never changed them.
    let row = GROUP_ROW as usize;
    assert_eq!(canon(&hash.finals, row), canon(&sk.finals, row));
    assert_eq!(
        hash.res.stages[1].output_bytes, merge.output_bytes,
        "merged rows must equal the unsplit group-by's rows"
    );
}

#[test]
fn suite_is_byte_identical_across_workers_and_fault_planes() {
    // Within the fixed SkewAware partitioner the determinism contract
    // is exact per-partition byte identity — across worker counts and
    // with stragglers, netfaults, speculation and crash recovery armed.
    let golden = run_suite(&base_cfg(&skew(), 1, false));
    for workers in [4usize, 8] {
        let r = run_suite(&base_cfg(&skew(), workers, false));
        assert_eq!(golden.finals, r.finals, "workers={workers}");
        assert_eq!(
            golden.res.job_time, r.res.job_time,
            "virtual time moved with worker count"
        );
    }
    let faulty = run_suite(&base_cfg(&skew(), 4, true));
    assert_eq!(golden.finals, faulty.finals, "fault plane moved bytes");
    assert_eq!(
        golden.res.stages[1].hot_keys_split,
        faulty.res.stages[1].hot_keys_split,
        "hot-key census must be a plan-time constant"
    );
}

#[test]
fn checkpoint_covers_merge_and_invalidation_recomputes_stage() {
    // One cluster, run the suite twice: the second run restores both
    // stages (merge included) without recomputing; then losing a merge
    // output invalidates exactly that stage's checkpoint.
    let cfg = base_cfg(&skew(), 2, false);
    let mut cluster = deploy(&cfg);
    let mut rt = RtEngine::load(None).unwrap();
    let join = RepartitionJoin::new(schema());
    let gb = GroupBy::new(schema());
    let input =
        stage_input(&mut cluster, &cfg, &join, 4 * MIB, SEED).unwrap();
    let pipe = JobPipeline::new("starjoin-cp")
        .stage(&join, cfg.clone())
        .stage(&gb, cfg.clone());
    let res1 = pipe.run(&mut cluster, &mut rt, SEED, &input);
    assert!(res1.ok(), "{:?}", res1.failed);
    let m1 = res1.merges[1].as_ref().expect("no merge ran");
    let fin1 = res1.final_output().unwrap();
    let outs1 =
        fetch_outputs(&mut cluster, &fin1.job, fin1.reduce.tasks.max(1));
    let (fjob, fn1) = (fin1.job.clone(), fin1.reduce.tasks);

    let res2 = pipe.run(&mut cluster, &mut rt, SEED, &input);
    assert!(res2.ok(), "{:?}", res2.failed);
    assert!(res2.restored.iter().all(|x| *x), "{:?}", res2.restored);
    assert_eq!(res2.checkpoints, 0, "no recompute, no new checkpoints");
    assert_eq!(res2.job_time.as_nanos(), 0);
    // The restored merge record carries the checkpointed shape, so the
    // final outputs stay resolvable through `final_output()`.
    let m2 = res2.merges[1].as_ref().expect("merge record lost on resume");
    assert_eq!(m2.output_bytes, m1.output_bytes);
    assert_eq!(m2.reduce.tasks, m1.reduce.tasks);
    let fin2 = res2.final_output().unwrap();
    assert_eq!(fin2.job, fjob);
    let outs2 = fetch_outputs(&mut cluster, &fjob, fn1.max(1));
    assert_eq!(outs1, outs2);

    // Lose one committed merge output: the stage-1 checkpoint (which
    // covers the merge) must fail validation and re-run stage + merge,
    // while stage 0 stays restored. Deterministic recompute: bytes
    // unchanged.
    let victim = (0..fn1.max(1))
        .map(|j| output_key(&fjob, j))
        .find(|k| cluster.stores.igfs.len_of(k).is_some())
        .expect("merge wrote at least one output");
    assert!(cluster.stores.igfs.remove(&victim));
    let res3 = pipe.run(&mut cluster, &mut rt, SEED, &input);
    assert!(res3.ok(), "{:?}", res3.failed);
    assert_eq!(res3.restored, vec![true, false],
               "only the stage owning the lost merge recomputes");
    assert!(res3.merges[1].is_some(), "merge re-ran with its stage");
    let outs3 = fetch_outputs(&mut cluster, &fjob, fn1.max(1));
    assert_eq!(outs1, outs3);
}
