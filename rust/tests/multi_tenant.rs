//! Multi-tenant JobServer acceptance pins.
//!
//! * A 4-tenant mixed-workload co-run (wordcount, grep, pagerank,
//!   aggregation query) over ONE shared cluster produces per-tenant
//!   outputs byte-identical to the same jobs run solo — at
//!   `{map,reduce}_workers ∈ {1, 4, 8}` and under reversed admission
//!   order — with nonzero cross-job warm-container reuse and nonzero
//!   per-tenant `CacheStats` in every `JobResult`.
//! * Two tenants with 3:1 shares over a saturated cluster finish in
//!   share-proportional virtual time (and swapping the shares swaps
//!   the finishing order — shares, not admission order, decide).
//! * Warm-pool regression: on a shared cluster with prewarm off, job 2
//!   records ZERO cold starts for containers job 1 already warmed.

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{
    output_key, run_job, stage_named_input, Cluster, JobServer,
    ServerResult, SystemConfig, Workload,
};
use marvel::net::NodeId;
use marvel::runtime::RtEngine;
use marvel::sim::SimNs;
use marvel::util::bytes::MIB;
use marvel::workloads::{AggregationQuery, Corpus, Grep, PageRank,
                        WordCount};

const SEED: u64 = 31;
const INPUT: u64 = 2 * MIB;

fn cfg(map_workers: usize, reduce_workers: usize) -> SystemConfig {
    let mut c = SystemConfig::marvel_igfs();
    c.map_workers = map_workers;
    c.reduce_workers = reduce_workers;
    c
}

fn deploy(base: &SystemConfig) -> Cluster {
    let mut cluster = ClusterSpec::default().deploy(base);
    cluster.stores.hdfs.block_size = 256 * 1024;
    cluster
}

/// Fetch a job's reducer outputs through the same chain the handoff
/// uses: IGFS (any tier) first, then HDFS, then S3.
fn fetch_outputs(
    cluster: &mut Cluster,
    job: &str,
    n: usize,
) -> Vec<Option<Vec<u8>>> {
    (0..n)
        .map(|j| {
            let key = output_key(job, j);
            if let Some((p, _)) =
                cluster.stores.igfs.get(&cluster.topo, NodeId(0), &key, 0)
            {
                return p.gather();
            }
            if cluster.stores.hdfs.namenode.stat(&key).is_some() {
                return cluster
                    .stores
                    .hdfs
                    .read(&cluster.topo, NodeId(0), &key, 0)
                    .ok()
                    .and_then(|(p, _, _, _)| p.gather());
            }
            cluster.stores.s3.get(&key).and_then(|p| p.gather())
        })
        .collect()
}

/// Run one workload solo on a fresh cluster; return its outputs.
fn solo_outputs(
    wl: &dyn Workload,
    base: &SystemConfig,
    rt: &mut RtEngine,
) -> (Vec<Option<Vec<u8>>>, SimNs) {
    let mut cluster = deploy(base);
    let input = stage_named_input(&mut cluster, base, wl, INPUT, SEED,
                                  &format!("solo/{}/in", wl.name()))
        .unwrap();
    let r = run_job(&mut cluster, base, wl, &input, rt, SEED);
    assert!(r.ok(), "solo {} failed: {:?}", wl.name(), r.failed);
    let outs = fetch_outputs(&mut cluster, &r.job, r.reduce.tasks.max(32));
    (outs, r.job_time)
}

struct Workloads {
    wc: WordCount,
    grep: Grep,
    pr: PageRank,
    agg: AggregationQuery,
}

impl Workloads {
    fn new(rt: &RtEngine) -> Workloads {
        let prefix = Corpus::new(2000, 1.07).prefix_of_rank(5, 2);
        Workloads {
            wc: WordCount::new(2000, 1.07, rt),
            grep: Grep::new(2000, 1.07, &prefix, rt),
            pr: PageRank::new(),
            agg: AggregationQuery::new(rt),
        }
    }

    fn all(&self) -> Vec<(&'static str, &dyn Workload)> {
        vec![
            ("t-wc", &self.wc),
            ("t-grep", &self.grep),
            ("t-pr", &self.pr),
            ("t-agg", &self.agg),
        ]
    }
}

/// Co-run the four tenants' jobs (in the given admission order) on one
/// shared cluster; return the server result plus each tenant's fetched
/// outputs, keyed by tenant name.
fn corun(
    base: &SystemConfig,
    rt: &mut RtEngine,
    wls: &Workloads,
    order: &[usize],
) -> (ServerResult, Vec<(String, Vec<Option<Vec<u8>>>)>) {
    let tenants = wls.all();
    let mut cluster = deploy(base);
    let mut inputs = Vec::new();
    for &i in order {
        let (name, wl) = &tenants[i];
        let path = format!("{name}/in");
        inputs.push(
            stage_named_input(&mut cluster, base, *wl, INPUT, SEED, &path)
                .unwrap(),
        );
    }
    let mut server = JobServer::new();
    for (name, _) in &tenants {
        server = server.tenant(name, 1);
    }
    for (k, &i) in order.iter().enumerate() {
        let (name, wl) = &tenants[i];
        server = server.job(name, *wl, base.clone(), &inputs[k], SEED);
    }
    let res = server.run(&mut cluster, rt);
    let mut outs = Vec::new();
    for run in &res.jobs {
        let jr = run.final_stage().unwrap();
        let fetched =
            fetch_outputs(&mut cluster, &jr.job, jr.reduce.tasks.max(32));
        outs.push((run.tenant.clone(), fetched));
    }
    (res, outs)
}

#[test]
fn four_tenant_mixed_corun_matches_solo_at_any_workers_and_order() {
    let mut rt = RtEngine::load(None).unwrap();
    let wls = Workloads::new(&rt);
    let base1 = cfg(1, 1);
    // Solo baselines at workers=1.
    let solo: Vec<(String, Vec<Option<Vec<u8>>>)> = wls
        .all()
        .iter()
        .map(|(name, wl)| {
            (name.to_string(), solo_outputs(*wl, &base1, &mut rt).0)
        })
        .collect();

    for workers in [1usize, 4, 8] {
        let base = cfg(workers, workers);
        for order in [vec![0, 1, 2, 3], vec![3, 2, 1, 0]] {
            let (res, outs) = corun(&base, &mut rt, &wls, &order);
            assert!(res.ok(), "co-run failed: {:?}",
                    res.jobs.iter().flat_map(|r| &r.stages)
                       .filter_map(|s| s.failed.clone())
                       .collect::<Vec<_>>());
            assert_eq!(res.jobs.len(), 4);
            // Byte-identical per-tenant outputs vs solo.
            for (tenant, fetched) in &outs {
                let (_, want) = solo
                    .iter()
                    .find(|s| &s.0 == tenant)
                    .expect("tenant has a solo baseline");
                assert_eq!(want, fetched,
                    "tenant {tenant} diverged at workers={workers}, \
                     order={order:?}");
            }
            // Nonzero cross-job warm reuse: every later admission
            // reuses containers earlier jobs (or prewarm) left warm.
            assert!(res.jobs[1..].iter().any(|r| r.cross_job_warm > 0),
                    "no cross-job warm reuse recorded");
            // Per-tenant CacheStats present in every JobResult (IGFS
            // shuffle) and in the tenant aggregates.
            for run in &res.jobs {
                let jr = run.final_stage().unwrap();
                assert!(jr.igfs.hits_dram > 0, "{}: {:?}", jr.job,
                        jr.igfs);
            }
            for rep in &res.tenants {
                assert_eq!(rep.jobs, 1);
                assert!(rep.igfs.hits_dram > 0, "{}", rep.name);
                assert!(rep.completion > SimNs::ZERO);
            }
            // All four share one virtual clock.
            let latest =
                res.jobs.iter().map(|r| r.completion).max().unwrap();
            assert_eq!(res.makespan, latest);
        }
    }
}

#[test]
fn tenants_share_cache_capacity_and_evict_each_other() {
    // Tight DRAM: the co-run overflows into the PMEM backing tier and
    // tenants evict each other — yet outputs stay byte-identical.
    let mut rt = RtEngine::load(None).unwrap();
    let wls = Workloads::new(&rt);
    let mut tight = cfg(2, 2);
    tight.igfs_capacity = 256 * 1024;
    let (res, outs) = corun(&tight, &mut rt, &wls, &[0, 1, 2, 3]);
    assert!(res.ok());
    let total_evictions: u64 =
        res.tenants.iter().map(|t| t.igfs.evictions).sum();
    assert!(total_evictions > 0, "256 KiB shared cache must evict");
    assert!(res.tenants.iter().any(|t| t.igfs.hits_backing > 0),
            "evicted entries served from backing tier");
    let solo1 = cfg(1, 1);
    for (tenant, fetched) in &outs {
        let (_, wl) = wls
            .all()
            .into_iter()
            .find(|t| t.0 == tenant.as_str())
            .unwrap();
        let (want, _) = solo_outputs(wl, &solo1, &mut rt);
        assert_eq!(&want, fetched,
                   "{tenant} diverged under cache pressure");
    }
}

/// Saturated deployment: 1 node, 4 slots — 8 splits per job queue
/// behind each other so shares govern the interleave.
fn small_spec() -> ClusterSpec {
    ClusterSpec { nodes: 1, slots_per_node: 4, ..Default::default() }
}

fn fairness_corun(
    share_a: u64,
    share_b: u64,
    rt: &mut RtEngine,
    wc: &WordCount,
) -> (SimNs, SimNs, SimNs) {
    let base = cfg(2, 2);
    let mut cluster = small_spec().deploy(&base);
    cluster.stores.hdfs.block_size = 256 * 1024;
    let in_a = stage_named_input(&mut cluster, &base, wc, INPUT, SEED,
                                 "a/in").unwrap();
    let in_b = stage_named_input(&mut cluster, &base, wc, INPUT, SEED,
                                 "b/in").unwrap();
    let res = JobServer::new()
        .tenant("a", share_a)
        .tenant("b", share_b)
        .job("a", wc, base.clone(), &in_a, SEED)
        .job("b", wc, base.clone(), &in_b, SEED)
        .run(&mut cluster, rt);
    assert!(res.ok(), "{:?}", res.failed);
    (
        res.tenant("a").unwrap().completion,
        res.tenant("b").unwrap().completion,
        res.makespan,
    )
}

#[test]
fn three_to_one_shares_finish_share_proportionally() {
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(2000, 1.07, &rt);
    // Solo baseline on the same saturated deployment.
    let base = cfg(2, 2);
    let mut solo_cluster = small_spec().deploy(&base);
    solo_cluster.stores.hdfs.block_size = 256 * 1024;
    let solo_in = stage_named_input(&mut solo_cluster, &base, &wc, INPUT,
                                    SEED, "a/in").unwrap();
    let solo =
        run_job(&mut solo_cluster, &base, &wc, &solo_in, &mut rt, SEED);
    assert!(solo.ok(), "{:?}", solo.failed);
    let t_solo = solo.job_time.as_secs_f64();
    let solo_outs = fetch_outputs(&mut solo_cluster, &solo.job,
                                  solo.reduce.tasks.max(32));

    let (a31, b31, mk31) = fairness_corun(3, 1, &mut rt, &wc);
    // The 3-share tenant finishes first; both pay for contention but
    // the co-run stays work-conserving (makespan ≈ 2× solo).
    //
    // Numeric bands re-derived after PR 4's cache-promotion fix (a
    // backing-tier hit now promotes back into DRAM, so repeat shuffle
    // reads got slightly cheaper and both ratios drift down a little).
    // The SFQ theory still pins the centers — the 3-share tenant near
    // 4/3× solo, the 1-share tenant near 2× solo — and the bands below
    // hold those centers with a ±~35 % margin on each side, wide
    // enough to absorb tier-pricing shifts while still failing on a
    // real fairness regression (a 3-share tenant at 2× solo, or a
    // 1-share tenant past 2.8×, means the shares stopped binding).
    // The ordinal assertions stay exact.
    assert!(a31 < b31, "share 3 must finish before share 1: {a31} {b31}");
    let (ra, rb) = (a31.as_secs_f64() / t_solo, b31.as_secs_f64() / t_solo);
    assert!(ra > 1.0, "contention cannot make tenant a faster: {ra}");
    assert!(ra < 1.9, "3-share tenant should be near 4/3× solo: {ra}");
    assert!(rb > 1.3 && rb < 2.8,
            "1-share tenant should be near 2× solo: {rb}");
    assert!(rb / ra > 1.15,
            "shares must visibly separate the tenants: {ra} vs {rb}");
    assert!(mk31.as_secs_f64() < 2.8 * t_solo, "not work-conserving");

    // Swapping the shares swaps the finishing order — shares decide,
    // not admission order (a is still admitted first).
    let (a13, b13, _) = fairness_corun(1, 3, &mut rt, &wc);
    assert!(b13 < a13, "swapped shares must swap the order");

    // Equal shares: near-equal completions on identical jobs.
    let (a11, b11, mk11) = fairness_corun(1, 1, &mut rt, &wc);
    let gap = if a11 > b11 { a11 - b11 } else { b11 - a11 };
    assert!(gap.as_secs_f64() < 0.35 * mk11.as_secs_f64(),
            "equal shares should finish close together: {a11} vs {b11}");

    // Fairness is a time-plane property only: co-run outputs are still
    // byte-identical to solo.
    let base2 = cfg(2, 2);
    let mut cluster = small_spec().deploy(&base2);
    cluster.stores.hdfs.block_size = 256 * 1024;
    let in_a = stage_named_input(&mut cluster, &base2, &wc, INPUT, SEED,
                                 "a/in").unwrap();
    let in_b = stage_named_input(&mut cluster, &base2, &wc, INPUT, SEED,
                                 "b/in").unwrap();
    let res = JobServer::new()
        .tenant("a", 3)
        .tenant("b", 1)
        .job("a", &wc, base2.clone(), &in_a, SEED)
        .job("b", &wc, base2.clone(), &in_b, SEED)
        .run(&mut cluster, &mut rt);
    assert!(res.ok());
    for run in &res.jobs {
        let jr = run.final_stage().unwrap();
        let outs = fetch_outputs(&mut cluster, &jr.job,
                                 jr.reduce.tasks.max(32));
        assert_eq!(outs, solo_outs, "{} diverged from solo", run.tenant);
    }
}

#[test]
fn warm_pool_survives_across_jobs_on_a_shared_cluster() {
    // Regression: Controller/Invoker pools used to be rebuilt per job
    // (every run deployed a fresh cluster). On a shared cluster with
    // prewarm disabled, job 1 pays the cold starts; job 2 must record
    // ZERO cold starts, reusing only containers job 1 warmed.
    let mut base = cfg(2, 2);
    base.prewarm = false;
    let mut cluster = ClusterSpec::default().deploy(&base);
    cluster.stores.hdfs.block_size = 256 * 1024;
    let mut m = marvel::coordinator::Marvel::new(
        ClusterSpec::default(), SEED,
    )
    .unwrap();
    let wc = WordCount::new(2000, 1.07, &m.rt);

    let r1 = m.run_shared(&mut cluster, &base, &wc, INPUT, "job1");
    assert!(r1.ok(), "{:?}", r1.failed);
    assert!(r1.cold_starts > 0, "first job on a cold cluster");

    let r2 = m.run_shared(&mut cluster, &base, &wc, INPUT, "job2");
    assert!(r2.ok(), "{:?}", r2.failed);
    assert_eq!(r2.cold_starts, 0,
               "job 2 must reuse job 1's warm containers");
    assert!(r2.warm_starts > 0, "and actually record the reuse");

    // The same two jobs through the JobServer agree.
    let mut cluster2 = ClusterSpec::default().deploy(&base);
    cluster2.stores.hdfs.block_size = 256 * 1024;
    let in1 = stage_named_input(&mut cluster2, &base, &wc, INPUT, SEED,
                                "s1/in").unwrap();
    let in2 = stage_named_input(&mut cluster2, &base, &wc, INPUT, SEED,
                                "s2/in").unwrap();
    let res = JobServer::new()
        .job("s1", &wc, base.clone(), &in1, SEED)
        .job("s2", &wc, base.clone(), &in2, SEED)
        .run(&mut cluster2, &mut m.rt);
    assert!(res.ok());
    assert!(res.jobs[0].stages[0].cold_starts > 0);
    assert_eq!(res.jobs[1].stages[0].cold_starts, 0);
    // Plan-time invoke/complete alternation keeps at most a handful of
    // containers idle at once, so the cross-job share is the warm
    // stock at admission — nonzero, bounded by total warm starts.
    assert!(res.jobs[1].cross_job_warm > 0);
    assert!(res.jobs[1].cross_job_warm
                <= res.jobs[1].stages[0].warm_starts);
}

#[test]
fn job_prefix_keeps_tenants_disjoint() {
    // Two tenants running the SAME workload on one cluster: key-prefix
    // namespacing keeps their shuffle and output key sets disjoint.
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(2000, 1.07, &rt);
    let base = cfg(2, 2);
    let mut cluster = deploy(&base);
    let in_a = stage_named_input(&mut cluster, &base, &wc, INPUT, SEED,
                                 "a/in").unwrap();
    let in_b = stage_named_input(&mut cluster, &base, &wc, INPUT, SEED,
                                 "b/in").unwrap();
    let res = JobServer::new()
        .job("a", &wc, base.clone(), &in_a, SEED)
        .job("b", &wc, base.clone(), &in_b, SEED)
        .run(&mut cluster, &mut rt);
    assert!(res.ok());
    let ja = &res.jobs[0].stages[0].job;
    let jb = &res.jobs[1].stages[0].job;
    assert_ne!(ja, jb);
    assert!(ja.starts_with("a/") && jb.starts_with("b/"));
    let oa = fetch_outputs(&mut cluster, ja, 32);
    let ob = fetch_outputs(&mut cluster, jb, 32);
    assert_eq!(oa, ob, "same workload+seed → same bytes, distinct keys");
    // Scrubbing tenant a's namespace leaves b's outputs intact.
    let removed = cluster.stores.clear_prefix(&format!("{ja}/"));
    assert!(removed > 0);
    assert_eq!(fetch_outputs(&mut cluster, jb, 32), ob);
    assert!(fetch_outputs(&mut cluster, ja, 32)
                .iter()
                .all(|o| o.is_none()));
}
