//! Degraded-mode I/O acceptance pins: network fault injection, flow
//! deadlines with backoff retries, and cache-blackout degradation.
//!
//! * A nonzero `NetFaultPlan` (link fault windows + flow deadlines)
//!   slows the job and blows deadlines but never moves a byte.
//! * A cache-node blackout between the map and reduce phases degrades
//!   shuffle reads down the storage tiers (HDFS write-through copies);
//!   outputs stay byte-identical to the fault-free run at
//!   `{map,reduce}_workers ∈ {1, 4, 8}`, and the report carries
//!   nonzero `flow_timeouts` and `degraded_reads`.
//! * The same blackout with degradation OFF fails the job — the
//!   fig10 ablation contract.
//! * All three fault axes (netfaults × stragglers/speculation ×
//!   crash recovery) compose without moving bytes.
//!
//! Fault windows live in absolute virtual seconds, so these tests
//! deploy quietly, stage input over the healthy network, and install
//! the windows afterwards — faults strike mid-run, not mid-staging.
//! Whether a window actually starves a deadline depends on where the
//! task flows land, so `timing_seed()` searches for a seed that does.

use std::sync::OnceLock;

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{
    output_key, run_job, stage_named_input, Cluster, JobResult, JobServer,
    StoreKind, SystemConfig,
};
use marvel::net::{NetFaultPlan, NodeId, StragglerProfile};
use marvel::runtime::RtEngine;
use marvel::sim::SimNs;
use marvel::util::bytes::MIB;
use marvel::workloads::WordCount;

const SEED: u64 = 13;
const INPUT: u64 = 8 * MIB;
const NODES: usize = 4;
const SLOTS: usize = 8;

fn base_cfg(workers: usize) -> SystemConfig {
    let mut c = SystemConfig::marvel_igfs();
    c.map_workers = workers;
    c.reduce_workers = workers;
    // Cold starts (500 ms) push every task flow into the fault-window
    // band, where a blackout can actually starve a deadline.
    c.prewarm = false;
    c
}

fn netfault_cfg(
    seed: u64,
    blackout: bool,
    degraded: bool,
    workers: usize,
) -> SystemConfig {
    let mut c = base_cfg(workers);
    c.netfaults = NetFaultPlan {
        seed,
        prob: 1.0,
        slowdown: 8.0,
        flow_timeout: SimNs::from_millis(250),
        degraded_tiers: degraded,
        lose_cachenodes: if blackout { vec![1] } else { vec![] },
    };
    c
}

/// Deploy WITHOUT the plan's windows (staging must cross a healthy
/// network); `run_wc` installs them right before the job runs.
fn deploy_quiet(cfg: &SystemConfig) -> Cluster {
    let mut quiet = cfg.clone();
    quiet.netfaults = NetFaultPlan::disabled();
    let mut cluster = ClusterSpec {
        nodes: NODES,
        slots_per_node: SLOTS,
        ..Default::default()
    }
    .deploy(&quiet);
    cluster.stores.hdfs.block_size = 256 * 1024; // 32 splits from 8 MiB
    cluster
}

/// Every reducer's output bytes for `job`, through the configured
/// output store.
fn collect_outputs(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    job: &str,
    n_reduces: usize,
) -> Vec<Option<Vec<u8>>> {
    (0..n_reduces)
        .map(|j| {
            let key = output_key(job, j);
            let p = match cfg.output_store {
                StoreKind::Igfs => cluster
                    .stores
                    .igfs
                    .get(&cluster.topo, NodeId(0), &key, 0)
                    .map(|(p, _)| p),
                StoreKind::Hdfs => cluster
                    .stores
                    .hdfs
                    .read(&cluster.topo, NodeId(0), &key, 0)
                    .ok()
                    .map(|(p, _, _, _)| p),
                StoreKind::S3 => cluster.stores.s3.get(&key),
            };
            p.map(|p| p.gather().expect("real output"))
        })
        .collect()
}

fn run_wc(cfg: &SystemConfig) -> (JobResult, Vec<Option<Vec<u8>>>, Cluster) {
    let mut cluster = deploy_quiet(cfg);
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(4000, 1.07, &rt);
    let input =
        stage_named_input(&mut cluster, cfg, &wc, INPUT, SEED, "wc/in")
            .unwrap();
    cfg.netfaults.install(&cluster.topo, &mut cluster.engine);
    let r = run_job(&mut cluster, cfg, &wc, &input, &mut rt, SEED);
    let outs = if r.ok() {
        collect_outputs(&mut cluster, cfg, &r.job, r.reduce.tasks)
    } else {
        Vec::new()
    };
    (r, outs, cluster)
}

/// A netfault seed whose windows blow flow deadlines on this testbed,
/// with and without the blackout armed (the two shapes the pins below
/// run). Found by running the job — whether a window starves a flow
/// past its deadline depends on where that flow lands in virtual time.
fn timing_seed() -> u64 {
    static CELL: OnceLock<u64> = OnceLock::new();
    *CELL.get_or_init(|| {
        (0..64u64)
            .find(|&s| {
                let (rb, _, _) = run_wc(&netfault_cfg(s, true, true, 1));
                if !(rb.ok()
                    && rb.flow_timeouts > 0
                    && rb.degraded_reads > 0)
                {
                    return false;
                }
                let (rf, _, _) = run_wc(&netfault_cfg(s, false, true, 1));
                rf.ok() && rf.flow_timeouts > 0
            })
            .expect("a deadline-blowing netfault seed exists in 64 draws")
    })
}

#[test]
fn netfault_plan_moves_time_never_bytes() {
    let (r0, o0, _) = run_wc(&base_cfg(1));
    assert!(r0.ok(), "{:?}", r0.failed);
    assert!(o0.iter().any(|o| o.as_ref().is_some_and(|b| !b.is_empty())));
    assert_eq!(r0.flow_timeouts, 0, "no plan, no deadlines");
    assert_eq!(r0.degraded_reads, 0);

    let (rf, of, _) = run_wc(&netfault_cfg(timing_seed(), false, true, 1));
    assert!(rf.ok(), "{:?}", rf.failed);
    assert_eq!(of, o0, "a fault plan must never move bytes");
    assert_eq!(rf.output_bytes, r0.output_bytes);
    assert_eq!(rf.intermediate_bytes, r0.intermediate_bytes);
    assert!(rf.flow_timeouts > 0, "the searched seed blows deadlines");
    assert_eq!(rf.degraded_reads, 0, "no blackout, nothing degrades");
    assert!(
        rf.job_time > r0.job_time,
        "starved + retried flows must slow the job: {} vs {}",
        rf.job_time,
        r0.job_time
    );
    // Deadline expiries are transport retries, never task attempts.
    assert_eq!(
        rf.task_attempts,
        (rf.map.tasks + rf.reduce.tasks) as u64,
        "flow retries must not inflate task attempts"
    );
}

#[test]
fn blackout_degrades_reads_but_bytes_never_move() {
    let (r0, o0, _) = run_wc(&base_cfg(1));
    assert!(r0.ok(), "{:?}", r0.failed);

    let mut seen = Vec::new();
    for workers in [1usize, 4, 8] {
        let (r, o, _) =
            run_wc(&netfault_cfg(timing_seed(), true, true, workers));
        assert!(r.ok(), "workers={workers}: {:?}", r.failed);
        assert_eq!(
            o, o0,
            "outputs diverged under blackout at workers={workers}"
        );
        assert_eq!(r.output_bytes, r0.output_bytes);
        assert!(r.flow_timeouts > 0, "workers={workers}");
        assert!(
            r.degraded_reads > 0,
            "node 1 owned shuffle keys, their reads must degrade"
        );
        assert!(r.job_time > r0.job_time, "degradation is not free");
        seen.push((r.job_time, r.flow_timeouts, r.degraded_reads));
    }
    // Worker counts fan out the data plane only: virtual time and
    // every fault counter are invariant.
    assert_eq!(seen[0], seen[1]);
    assert_eq!(seen[0], seen[2]);
}

#[test]
fn blackout_without_degradation_fails_the_job() {
    // Ablation (fig10's degraded-off leg): same blackout, no tier
    // fallback — the gather hits the manifest "lost" error and the job
    // fails instead of reducing over a hole. Plan windows are not the
    // trigger, so any seed works; the failure is plan-time.
    let (r, _, _) = run_wc(&netfault_cfg(0, true, false, 1));
    let msg = r.failed.expect("blackout without degradation must fail");
    assert!(msg.contains("lost"), "unexpected failure: {msg}");

    // Windows alone (no blackout) never fail a job, degraded or not.
    let (r, _, _) = run_wc(&netfault_cfg(0, false, false, 1));
    assert!(r.ok(), "{:?}", r.failed);
}

#[test]
fn degraded_mode_composes_with_crashes_and_speculation() {
    let (_, o0, _) = run_wc(&base_cfg(1));

    let mut c = netfault_cfg(timing_seed(), true, true, 2);
    c.stragglers = StragglerProfile { seed: 7, prob: 0.4, slowdown: 8.0 };
    c.speculation.enabled = true;
    c.failures.crash_prob = 0.5;
    c.failures.max_failures_per_task = 2;
    c.failures.seed = 9;
    c.recovery.max_attempts = 3;
    c.recovery.interval_bytes = 64 * 1024;
    // Nonzero backoff ladder for both crashed attempts and timed-out
    // flows (the ZERO default keeps legacy recovery timings pinned).
    c.recovery.backoff_base = SimNs::from_millis(100);
    let (r, o, mut cluster) = run_wc(&c);
    assert!(r.ok(), "{:?}", r.failed);
    assert_eq!(o, o0, "three fault axes together moved bytes");
    assert!(r.degraded_reads > 0, "blackout still degrades reads");
    assert!(r.checkpoints > 0, "armed stateful plan checkpoints");
    assert_eq!(
        cluster.stores.clear_prefix(&format!("{}/spec/", r.job)),
        0,
        "speculative scratch keys must already be scrubbed"
    );
}

#[test]
fn blackout_under_corun_matches_solo_and_rolls_up() {
    // Blackout without windows (prob = 0): deterministic degraded
    // gathers, no deadline timing in play. Both tenants' outputs must
    // match the solo fault-free run and the per-tenant report must
    // roll the new counters up.
    let (_, o0, _) = run_wc(&base_cfg(1));

    let mut base = base_cfg(2);
    base.netfaults.lose_cachenodes = vec![1];
    let mut cluster = deploy_quiet(&base);
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(4000, 1.07, &rt);
    let in_a = stage_named_input(&mut cluster, &base, &wc, INPUT, SEED,
                                 "alice/in")
        .unwrap();
    let in_b = stage_named_input(&mut cluster, &base, &wc, INPUT, SEED,
                                 "bob/in")
        .unwrap();
    let res = JobServer::new()
        .tenant("alice", 3)
        .tenant("bob", 1)
        .job("alice", &wc, base.clone(), &in_a, SEED)
        .job("bob", &wc, base.clone(), &in_b, SEED)
        .run(&mut cluster, &mut rt);
    assert!(res.ok(), "{:?}", res.failed);
    for run in &res.jobs {
        let jr = run.final_stage().unwrap();
        let outs =
            collect_outputs(&mut cluster, &base, &jr.job, jr.reduce.tasks);
        assert_eq!(outs, o0, "tenant {} diverged from solo", run.tenant);
    }
    for t in &res.tenants {
        let want: u64 = res
            .jobs
            .iter()
            .filter(|j| j.tenant == t.name)
            .flat_map(|j| &j.stages)
            .map(|s| s.degraded_reads)
            .sum();
        assert_eq!(t.degraded_reads, want, "{}", t.name);
        assert_eq!(t.flow_timeouts, 0, "no windows, no deadlines");
    }
    // The first planned job wrote shuffle keys to node 1 before the
    // blackout dropped it from the partition map; later jobs place
    // around the hole, so only the total is guaranteed nonzero.
    let total: u64 = res.tenants.iter().map(|t| t.degraded_reads).sum();
    assert!(total > 0, "co-run blackout must degrade some gathers");
}
