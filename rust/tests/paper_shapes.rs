//! Fast regression guards on the paper's headline *shapes* (small-scale
//! versions of the bench assertions, so `cargo test` alone catches
//! calibration drift without running the full sweeps).

use marvel::coordinator::{reduction, ClusterSpec, Marvel};
use marvel::mapreduce::{CombinerMode, SystemConfig};
use marvel::metrics::tags;
use marvel::net::DeviceRole;
use marvel::workloads::{AggregationQuery, JoinQuery, WordCount};

const GB: u64 = 1_000_000_000;

#[test]
fn fig4_shape_at_2gb() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).unwrap();
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let r = m.compare(
        &[
            SystemConfig::corral_lambda(),
            SystemConfig::marvel_hdfs_paper(),
            SystemConfig::marvel_igfs_paper(),
        ],
        &wc,
        2 * GB,
    );
    assert!(r.iter().all(|x| x.ok()));
    let red = reduction(&r[0], &r[2]);
    assert!(red > 0.75 && red < 0.95,
            "fig4 2GB reduction drifted: {red}");
    assert!(r[1].job_time >= r[2].job_time, "IGFS lost to HDFS");
}

#[test]
fn lambda_quota_boundary() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).unwrap();
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    assert!(m.run(&SystemConfig::corral_lambda(), &wc, 15 * GB).ok());
    assert!(!m.run(&SystemConfig::corral_lambda(), &wc, 16 * GB).ok());
    assert!(m.run(&SystemConfig::marvel_igfs(), &wc, 16 * GB).ok());
}

#[test]
fn table1_expansion_regimes() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).unwrap();
    let cfg = SystemConfig::onprem(DeviceRole::Pmem, false);
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let r = m.run(&cfg, &wc, GB);
    let ratio = r.intermediate_bytes as f64 / r.input_bytes as f64;
    assert!((ratio - 5.5).abs() < 1.0, "wordcount expansion {ratio}");

    let agg = AggregationQuery::new(&m.rt);
    let r = m.run(&cfg, &agg, GB);
    let ratio = r.intermediate_bytes as f64 / r.input_bytes as f64;
    assert!((ratio - 1.66).abs() < 0.3, "aggregation expansion {ratio}");

    let join = JoinQuery::new();
    let r = m.run(&cfg, &join, GB);
    let ratio = r.intermediate_bytes as f64 / r.input_bytes as f64;
    assert!((ratio - 3.97).abs() < 0.6, "join expansion {ratio}");
}

#[test]
fn fig1_device_ordering_at_1gb() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).unwrap();
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let pmem = m.run(&SystemConfig::onprem(DeviceRole::Pmem, false), &wc, GB);
    let ssd = m.run(&SystemConfig::onprem(DeviceRole::Ssd, false), &wc, GB);
    let s3 = m.run(&SystemConfig::corral_lambda(), &wc, GB);
    assert!(pmem.job_time < ssd.job_time, "pmem !< ssd");
    assert!(ssd.job_time < s3.job_time, "ssd !< s3");
}

#[test]
fn fig6_igfs_throughput_dominates() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).unwrap();
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let st = [tags::INTERMEDIATE_WRITE, tags::INTERMEDIATE_READ];
    let h = m.run(&SystemConfig::marvel_hdfs_paper(), &wc, 2 * GB);
    let g = m.run(&SystemConfig::marvel_igfs_paper(), &wc, 2 * GB);
    assert!(g.io.gbps_over_makespan(&st) >= h.io.gbps_over_makespan(&st));
}

#[test]
fn combiner_ablation_shape() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).unwrap();
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let with = m.run(&SystemConfig::marvel_igfs(), &wc, GB);
    let mut cfg = SystemConfig::marvel_igfs();
    cfg.combiner = CombinerMode::None;
    let without = m.run(&cfg, &wc, GB);
    assert!(with.intermediate_bytes * 10 < without.intermediate_bytes);
    assert!(with.job_time <= without.job_time);
}

#[test]
fn grep_cheaper_shuffle_than_wordcount() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).unwrap();
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let prefix =
        marvel::workloads::Corpus::new(10_000, 1.07).prefix_of_rank(5, 2);
    let grep = marvel::workloads::Grep::new(10_000, 1.07, &prefix, &m.rt);
    let cfg = SystemConfig::marvel_igfs_paper();
    let a = m.run(&cfg, &wc, GB);
    let b = m.run(&cfg, &grep, GB);
    assert!(b.intermediate_bytes * 5 < a.intermediate_bytes,
            "grep shuffle should be far smaller: {} vs {}",
            b.intermediate_bytes, a.intermediate_bytes);
}
