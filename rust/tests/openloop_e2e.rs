//! Open-loop serving acceptance pins: seed-driven arrivals, admission
//! control, weighted-fair queueing for in-flight job tokens, and
//! elastic warm-pool autoscaling.
//!
//! * Same seeds ⇒ an identical admission/rejection log AND
//!   byte-identical per-tenant outputs at `{map,reduce}_workers ∈
//!   {1, 4, 8}` — the open-loop determinism contract. Admission is a
//!   plan-time estimator over `(schedule, config)` alone, so worker
//!   counts cannot perturb it; outputs come from the eager data plane,
//!   which is worker-count invariant by construction.
//! * A saturating burst engages rejections (offered = admitted +
//!   rejected), and the admitted backlog drains through the weighted
//!   fair queue without deadlock — every admitted job completes.
//! * With `prewarm = false` and autoscaling armed, the serve reports
//!   nonzero warm starts and scale-ups, and the cold-start rate falls
//!   from the first third of admitted jobs to the last third.

use marvel::coordinator::ClusterSpec;
use marvel::faas::AutoscaleConfig;
use marvel::mapreduce::{
    output_key, ArrivalConfig, ArrivalModel, Cluster, OpenLoopServer,
    ServerResult, StoreKind, SystemConfig, TenantClass,
};
use marvel::net::NodeId;
use marvel::runtime::RtEngine;
use marvel::sim::SimNs;
use marvel::util::bytes::MIB;
use marvel::workloads::WordCount;

const INPUT: u64 = MIB;
const NODES: usize = 4;
const SLOTS: usize = 8;

fn base_cfg(workers: usize) -> SystemConfig {
    let mut c = SystemConfig::marvel_igfs();
    c.map_workers = workers;
    c.reduce_workers = workers;
    c.arrivals = ArrivalConfig {
        model: ArrivalModel::Poisson { rate: 1.0 },
        seed: 42,
        horizon: SimNs::from_secs_f64(60.0),
        max_jobs: 10,
        classes: vec![
            TenantClass::new("an", 3, 3),
            TenantClass::new("batch", 1, 1),
        ],
        max_inflight: 2,
        queue_cap: 2,
        est_service: SimNs::from_secs_f64(2.0),
    };
    c
}

fn run_serve(cfg: &SystemConfig) -> (ServerResult, Cluster) {
    let mut cluster = ClusterSpec {
        nodes: NODES,
        slots_per_node: SLOTS,
        ..Default::default()
    }
    .deploy(cfg);
    cluster.stores.hdfs.block_size = 256 * 1024; // 4 splits from 1 MiB
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(800, 1.07, &rt);
    let res = OpenLoopServer::new(&wc, cfg.clone(), INPUT)
        .serve(&mut cluster, &mut rt);
    (res, cluster)
}

/// Every reducer's output bytes for `job`, through the configured
/// output store.
fn collect_outputs(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    job: &str,
    n_reduces: usize,
) -> Vec<Option<Vec<u8>>> {
    (0..n_reduces)
        .map(|j| {
            let key = output_key(job, j);
            let p = match cfg.output_store {
                StoreKind::Igfs => cluster
                    .stores
                    .igfs
                    .get(&cluster.topo, NodeId(0), &key, 0)
                    .map(|(p, _)| p),
                StoreKind::Hdfs => cluster
                    .stores
                    .hdfs
                    .read(&cluster.topo, NodeId(0), &key, 0)
                    .ok()
                    .map(|(p, _, _, _)| p),
                StoreKind::S3 => cluster.stores.s3.get(&key),
            };
            p.map(|p| p.gather().expect("real output"))
        })
        .collect()
}

#[test]
fn same_seeds_same_admissions_and_bytes_at_any_worker_count() {
    let mut logs = Vec::new();
    let mut outputs = Vec::new();
    for workers in [1usize, 4, 8] {
        let cfg = base_cfg(workers);
        let (res, mut cluster) = run_serve(&cfg);
        assert!(res.ok(), "workers={workers}: {:?}", res.failed);
        let ol = res.open_loop.as_ref().expect("open-loop report");
        assert!(ol.offered > 0);
        assert_eq!(ol.offered, ol.admitted + ol.rejected);
        assert_eq!(res.jobs.len(), ol.admitted as usize);
        assert!(res.jobs.iter().all(|j| j.ok()), "workers={workers}");
        logs.push(ol.decisions.clone());
        let outs: Vec<(String, Vec<Option<Vec<u8>>>)> = res
            .jobs
            .iter()
            .map(|run| {
                let jr = &run.stages[0];
                let o = collect_outputs(
                    &mut cluster,
                    &cfg,
                    &jr.job,
                    jr.reduce.tasks,
                );
                (jr.job.clone(), o)
            })
            .collect();
        assert!(outs.iter().any(|(_, o)| {
            o.iter().any(|b| b.as_ref().is_some_and(|b| !b.is_empty()))
        }));
        outputs.push(outs);
    }
    // Half 1 of the contract: identical admission logs.
    assert_eq!(logs[0], logs[1], "admission log moved at workers=4");
    assert_eq!(logs[0], logs[2], "admission log moved at workers=8");
    // Half 2: byte-identical per-tenant outputs, job for job.
    assert_eq!(outputs[0], outputs[1], "bytes moved at workers=4");
    assert_eq!(outputs[0], outputs[2], "bytes moved at workers=8");
}

#[test]
fn saturating_burst_engages_rejections_without_deadlock() {
    let mut cfg = base_cfg(2);
    // 12 simultaneous arrivals against 2 virtual servers + 2 queue
    // slots: exactly 4 admit, 8 bounce, in arrival order.
    cfg.arrivals.model = ArrivalModel::Trace(vec![5; 12]);
    cfg.arrivals.max_jobs = 12;
    let (res, _) = run_serve(&cfg);
    assert!(res.ok(), "{:?}", res.failed);
    let ol = res.open_loop.as_ref().expect("open-loop report");
    assert_eq!(ol.offered, 12);
    assert_eq!(ol.admitted, 4);
    assert_eq!(ol.rejected, 8);
    assert_eq!(
        ol.decisions.iter().filter(|d| d.admitted).count(),
        4,
        "decision log disagrees with the tally"
    );
    // The admitted backlog drained at max_inflight concurrency through
    // the weighted fair queue — no deadlock, every job finished.
    assert_eq!(res.jobs.len(), 4);
    assert!(res.jobs.iter().all(|j| j.ok()));
    // Queueing is visible: someone waited for a job token.
    assert!(ol.queue_wait_ms.p99 > 0.0, "a 12-burst must queue");
    // Rejected arrivals left no residue: per-class tallies reconcile.
    let (off, adm, rej) = ol.classes.iter().fold((0, 0, 0), |acc, c| {
        (acc.0 + c.offered, acc.1 + c.admitted, acc.2 + c.rejected)
    });
    assert_eq!((off, adm, rej), (12, 4, 8));
}

#[test]
fn autoscaling_warms_the_pool_as_arrivals_ramp() {
    let mut cfg = base_cfg(2);
    // Every container starts cold unless the autoscaler prewarms it.
    cfg.prewarm = false;
    // A steady 2 jobs/s trace, all admitted (generous budget).
    cfg.arrivals.model =
        ArrivalModel::Trace((0..18u64).map(|i| i * 500).collect());
    cfg.arrivals.max_jobs = 18;
    cfg.arrivals.max_inflight = 6;
    cfg.arrivals.queue_cap = 18;
    cfg.autoscale = AutoscaleConfig {
        enabled: true,
        warm_per_rate: 8.0,
        up_threshold: 1.1,
        down_threshold: 0.5,
        min_warm: 0,
        max_warm: 64,
        window: SimNs::from_secs_f64(30.0),
    };
    let (res, _) = run_serve(&cfg);
    assert!(res.ok(), "{:?}", res.failed);
    let ol = res.open_loop.as_ref().expect("open-loop report");
    assert_eq!(ol.rejected, 0, "budget was sized to admit everything");
    assert!(ol.scale_ups > 0, "a ramping rate must scale the pool up");
    assert!(ol.warm_starts > 0, "prewarmed containers must get hits");
    // Cold-start *rate* falls as the warm pool catches up: compare the
    // first third of admitted jobs against the last third.
    let cold_rate = |runs: &[marvel::mapreduce::JobRun]| {
        let (c, w) = runs.iter().flat_map(|r| &r.stages).fold(
            (0u64, 0u64),
            |(c, w), jr| (c + jr.cold_starts, w + jr.warm_starts),
        );
        c as f64 / (c + w).max(1) as f64
    };
    let n = res.jobs.len();
    assert!(n >= 9, "expected the full trace admitted, got {n}");
    let first = cold_rate(&res.jobs[..n / 3]);
    let last = cold_rate(&res.jobs[n - n / 3..]);
    assert!(
        last < first,
        "cold-start rate must fall: first {first:.2}, last {last:.2}"
    );
}
