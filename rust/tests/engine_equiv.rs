//! Differential determinism suite for the DES engine cores (ISSUE 9).
//!
//! The production hot path (hierarchical timing wheel + incremental
//! per-component flow re-rates) must be *observationally identical* to
//! the retained naive reference core (binary-heap timers + full
//! progressive-filling recomputes), which preserves the pre-overhaul
//! semantics. Randomized stage programs — delays × flows × barriers ×
//! cancels × classes × retries × capacity windows, seeds via
//! `util::prop` — are replayed through both cores and every observable
//! is compared: the `run()` result (including deadlock messages),
//! per-proc states and timestamps, flow/crash/timeout logs, barrier
//! opening times, and the label-prefix census queries.
//!
//! Resource capacities and window factors are dyadic on purpose: the
//! max–min fair-share arithmetic is then exact in f64, so rate
//! comparisons use `to_bits`-grade equality (via Debug formatting of
//! the exact timestamps), not tolerances.

use marvel::prop_assert;
use marvel::sim::{Engine, ProcId, SimNs, Stage};
use marvel::util::prop::{check, Gen};

/// Abstract stage: indices instead of engine ids, so the same program
/// can be compiled into two engines.
#[derive(Clone)]
enum Abs {
    Delay(u64),
    Acquire(usize),
    Release(usize),
    Flow { bytes: f64, path: Vec<usize>, tag: u32, timeout_ms: Option<u64> },
    Arrive(usize),
    Await(usize),
    Crash(String),
    Fail(String),
    Cancel(usize),
}

struct ProcSpec {
    label: String,
    class: u32,
    speed: f64,
    /// `(base_ms, cap_ms, max)` flow-retry policy, when armed.
    retry: Option<(u64, u64, u32)>,
    stages: Vec<Abs>,
}

struct Spec {
    pools: Vec<usize>,
    resources: Vec<f64>,
    windows: Vec<(usize, f64, f64, f64)>,
    barrier_targets: Vec<usize>,
    class_weights: Vec<(u32, u64)>,
    procs: Vec<ProcSpec>,
    /// `(proc, stages)` applied via `append_stages` after every spawn —
    /// the non-contiguous op-arena path the speculation race uses.
    appends: Vec<(usize, Vec<Abs>)>,
}

/// A 1–2 hop flow over distinct resources; dyadic byte counts keep the
/// fair-share arithmetic exact.
fn gen_flow(g: &mut Gen, n_res: usize) -> Abs {
    let first = g.rng.below(n_res as u64) as usize;
    let mut path = vec![first];
    if n_res > 1 && g.rng.chance(0.5) {
        let second = (first + 1 + g.rng.below((n_res - 1) as u64) as usize) % n_res;
        path.push(second);
    }
    Abs::Flow {
        bytes: [1000.0, 4000.0, 16000.0, 64000.0][g.rng.below(4) as usize]
            * (1 + g.rng.below(4)) as f64,
        path,
        tag: g.rng.below(8) as u32,
        timeout_ms: if g.rng.chance(0.3) { Some(50 + g.rng.below(500)) } else { None },
    }
}

fn gen_stage(
    g: &mut Gen,
    held: &mut Vec<usize>,
    n_pools: usize,
    n_res: usize,
    n_bars: usize,
    n_procs: usize,
    arrivals: &mut [usize],
    label: &str,
) -> Abs {
    // Delay values repeat a small menu so equal-timestamp ties (the
    // FIFO seq tiebreak) occur constantly.
    const DELAYS: [u64; 6] = [0, 100_000, 100_000, 1_000_000, 2_500_000, 40_000_000];
    match g.rng.below(100) {
        0..=29 => Abs::Delay(
            *g.pick(&DELAYS) + if g.rng.chance(0.3) { g.rng.below(5_000_000) } else { 0 },
        ),
        30..=44 => {
            let p = g.rng.below(n_pools as u64) as usize;
            held.push(p);
            Abs::Acquire(p)
        }
        45..=54 => match held.pop() {
            Some(p) => Abs::Release(p),
            None => gen_flow(g, n_res),
        },
        55..=74 => gen_flow(g, n_res),
        75..=84 => {
            let b = g.rng.below(n_bars as u64) as usize;
            arrivals[b] += 1;
            Abs::Arrive(b)
        }
        85..=89 => Abs::Await(g.rng.below(n_bars as u64) as usize),
        90..=94 => Abs::Crash(format!("{label} attempt died")),
        95..=96 => Abs::Fail(format!("{label} gave up")),
        _ => Abs::Cancel(g.rng.below(n_procs as u64) as usize),
    }
}

fn gen_spec(g: &mut Gen) -> Spec {
    let n_pools = 1 + g.usize_up_to(3);
    let pools: Vec<usize> =
        (0..n_pools).map(|_| 1 + g.rng.below(4) as usize).collect();
    let n_res = 1 + g.usize_up_to(3);
    let resources: Vec<f64> = (0..n_res)
        .map(|_| [40.0, 100.0, 250.0, 1000.0][g.rng.below(4) as usize])
        .collect();
    let windows = (0..g.usize_up_to(2))
        .map(|_| {
            (
                g.rng.below(n_res as u64) as usize,
                g.rng.below(4) as f64 * 0.5,
                2.0 + g.rng.below(4) as f64 * 0.5,
                [0.0, 0.5][g.rng.below(2) as usize],
            )
        })
        .collect();
    let n_bars = 1 + g.usize_up_to(2);
    let mut arrivals = vec![0usize; n_bars];
    let class_weights: Vec<(u32, u64)> =
        (0..3).map(|c| (c, 1 + g.rng.below(4))).collect();
    let n_procs = 2 + g.usize_up_to(30);
    let mut procs = Vec::with_capacity(n_procs);
    for j in 0..n_procs {
        let label = format!("g{}/p{:03}", j % 3, j);
        let class = g.rng.below(3) as u32;
        let speed = *g.pick(&[1.0, 1.0, 1.0, 0.5, 0.25, 2.0]);
        let retry = if g.rng.chance(0.3) {
            Some((
                10 + g.rng.below(90),
                200 + g.rng.below(800),
                1 + g.rng.below(3) as u32,
            ))
        } else {
            None
        };
        let n_stages = 1 + g.usize_up_to(7);
        let mut held = Vec::new();
        let stages = (0..n_stages)
            .map(|_| {
                gen_stage(
                    g, &mut held, n_pools, n_res, n_bars, n_procs,
                    &mut arrivals, &label,
                )
            })
            .collect();
        procs.push(ProcSpec { label, class, speed, retry, stages });
    }
    // A few post-spawn appends: a Cancel race tail plus an Arrive,
    // exercising the non-contiguous program-segment path.
    let appends = (0..g.usize_up_to(3))
        .map(|_| {
            let target = g.rng.below(n_procs as u64) as usize;
            let victim = g.rng.below(n_procs as u64) as usize;
            let b = g.rng.below(n_bars as u64) as usize;
            arrivals[b] += 1;
            (target, vec![Abs::Cancel(victim), Abs::Arrive(b)])
        })
        .collect();
    // Targets mostly open; occasionally one arrival short, so the
    // deadlock path (and its error message) is differential too.
    let barrier_targets = arrivals
        .iter()
        .map(|&a| a + if g.rng.chance(0.12) { 1 } else { 0 })
        .collect();
    Spec {
        pools,
        resources,
        windows,
        barrier_targets,
        class_weights,
        procs,
        appends,
    }
}

fn lower(stages: &[Abs], pools: &[marvel::sim::PoolId],
         res: &[marvel::sim::ResourceId],
         bars: &[marvel::sim::BarrierId]) -> Vec<Stage> {
    stages
        .iter()
        .map(|s| match s {
            Abs::Delay(ns) => Stage::Delay(SimNs::from_nanos(*ns)),
            Abs::Acquire(p) => Stage::Acquire(pools[*p]),
            Abs::Release(p) => Stage::Release(pools[*p]),
            Abs::Flow { bytes, path, tag, timeout_ms } => Stage::Flow {
                bytes: *bytes,
                path: path.iter().map(|r| res[*r]).collect(),
                tag: *tag,
                timeout: timeout_ms.map(SimNs::from_millis),
            },
            Abs::Arrive(b) => Stage::Arrive(bars[*b]),
            Abs::Await(b) => Stage::Await(bars[*b]),
            Abs::Crash(m) => Stage::Crash(m.clone()),
            Abs::Fail(m) => Stage::Fail(m.clone()),
            Abs::Cancel(t) => Stage::Cancel(ProcId(*t)),
        })
        .collect()
}

fn build(spec: &Spec, reference: bool) -> Engine {
    let mut e = Engine::new();
    if reference {
        e.use_reference_core();
    }
    for &(c, w) in &spec.class_weights {
        e.set_class_weight(c, w);
    }
    let pools: Vec<_> =
        spec.pools.iter().map(|&c| e.add_pool(c)).collect();
    let res: Vec<_> = spec
        .resources
        .iter()
        .enumerate()
        .map(|(i, &c)| e.add_resource(&format!("r{i}"), c))
        .collect();
    for &(r, t0, t1, f) in &spec.windows {
        e.flows.add_capacity_window(res[r], t0, t1, f);
    }
    let bars: Vec<_> = spec
        .barrier_targets
        .iter()
        .map(|&t| e.add_barrier(t))
        .collect();
    let mut ids = Vec::with_capacity(spec.procs.len());
    for p in &spec.procs {
        let id = e.spawn_scaled(
            &p.label,
            p.class,
            p.speed,
            lower(&p.stages, &pools, &res, &bars),
        );
        if let Some((base_ms, cap_ms, max)) = p.retry {
            e.set_flow_retry(
                id,
                SimNs::from_millis(base_ms),
                SimNs::from_millis(cap_ms),
                max,
            );
        }
        ids.push(id);
    }
    for (target, stages) in &spec.appends {
        e.append_stages(ids[*target], lower(stages, &pools, &res, &bars));
    }
    e
}

/// Every observable of a finished engine, formatted for exact
/// comparison (f64s via to_bits, timestamps via raw nanos).
fn fingerprint(e: &Engine, spec: &Spec, r: &Result<SimNs, String>) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    writeln!(s, "result: {r:?}").unwrap();
    for j in 0..spec.procs.len() {
        let id = ProcId(j);
        writeln!(
            s,
            "proc {j} {:?} started={} finished={}",
            e.state(id),
            e.started_at(id).as_nanos(),
            e.finished_at(id).as_nanos(),
        )
        .unwrap();
    }
    for f in &e.flow_log {
        writeln!(
            s,
            "flow tag={} bytes={:x} [{}, {}]",
            f.tag,
            f.bytes.to_bits(),
            f.start.as_nanos(),
            f.end.as_nanos(),
        )
        .unwrap();
    }
    for c in &e.crash_log {
        writeln!(s, "crash @{} {} {}", c.at.as_nanos(), c.proc_label, c.what)
            .unwrap();
    }
    for t in &e.timeout_log {
        writeln!(s, "tmo @{} {} {}", t.at.as_nanos(), t.proc_label, t.what)
            .unwrap();
    }
    for b in 0..spec.barrier_targets.len() {
        writeln!(
            s,
            "bar {b} {:?}",
            e.barrier_opened_at(marvel::sim::BarrierId(b))
                .map(|t| t.as_nanos()),
        )
        .unwrap();
    }
    for prefix in ["", "g0/", "g1/", "g2/"] {
        writeln!(
            s,
            "census {prefix:?}: fail={:?} crashes={} tmo={} cancelled={:?} \
             failures={:?}",
            e.failure_with_prefix(prefix),
            e.crashes_with_prefix(prefix),
            e.timeouts_with_prefix(prefix),
            e.cancelled_with_prefix(prefix),
            e.failures(),
        )
        .unwrap();
    }
    s
}

#[test]
fn randomized_programs_are_identical_on_both_cores() {
    check("engine-equiv", 60, |g| {
        let spec = gen_spec(g);
        let mut fast = build(&spec, false);
        let mut reference = build(&spec, true);
        let rf = fast.run();
        let rr = reference.run();
        let a = fingerprint(&fast, &spec, &rf);
        let b = fingerprint(&reference, &spec, &rr);
        prop_assert!(
            a == b,
            "cores diverged:\n--- wheel+incremental ---\n{a}\n\
             --- reference ---\n{b}"
        );
        Ok(())
    });
}

#[test]
fn dense_equal_timestamp_storm_keeps_fifo_order() {
    // 1500 procs wake at the same virtual instant, then serialize
    // through one slot: the (time, seq) FIFO tiebreak fully determines
    // the grant order, so per-proc finish times must match the
    // reference heap exactly.
    let build = |reference: bool| {
        let mut e = Engine::new();
        if reference {
            e.use_reference_core();
        }
        let pool = e.add_pool(1);
        for i in 0..1500u32 {
            e.spawn(&format!("s{i:04}"), vec![
                Stage::Delay(SimNs::from_millis(10)),
                Stage::Acquire(pool),
                Stage::Delay(SimNs::from_micros(3)),
                Stage::Release(pool),
            ]);
        }
        let end = e.run().unwrap();
        let finishes: Vec<u64> =
            (0..1500).map(|i| e.finished_at(ProcId(i)).as_nanos()).collect();
        (end, finishes)
    };
    let (end_w, fin_w) = build(false);
    let (end_r, fin_r) = build(true);
    assert_eq!(end_w, end_r);
    assert_eq!(fin_w, fin_r, "storm grant order diverged");
    // FIFO: finish times strictly increase with spawn order.
    assert!(fin_w.windows(2).all(|w| w[0] < w[1]));
}

#[test]
fn long_horizon_delays_cascade_identically() {
    // Delays spanning ten orders of magnitude — nanoseconds to a day —
    // land across every wheel level plus the overflow list; cascades
    // on pop must preserve exact order vs the reference heap.
    let horizons: [u64; 8] = [
        1,
        1_000,
        1_000_000,
        1_000_000_000,
        60_000_000_000,
        3_600_000_000_000,
        86_400_000_000_000,
        2 << 59,
    ];
    let build = |reference: bool| {
        let mut e = Engine::new();
        if reference {
            e.use_reference_core();
        }
        let bar = e.add_barrier(horizons.len() * 4);
        for (i, &h) in horizons.iter().enumerate() {
            for k in 0..4u64 {
                e.spawn(&format!("h{i}k{k}"), vec![
                    Stage::Delay(SimNs::from_nanos(h + k * 17)),
                    Stage::Arrive(bar),
                ]);
            }
        }
        e.spawn("sink", vec![Stage::Await(bar)]);
        let end = e.run().unwrap();
        let fins: Vec<u64> = (0..horizons.len() * 4)
            .map(|i| e.finished_at(ProcId(i)).as_nanos())
            .collect();
        (end, fins)
    };
    assert_eq!(build(false), build(true));
}

#[test]
fn flow_retry_blackout_paths_match() {
    // The degraded-mode composite: blackout window + flow deadlines +
    // capped backoff retries + a slot handed back through the fair
    // queue. Exact timeline equality across cores.
    let build = |reference: bool| {
        let mut e = Engine::new();
        if reference {
            e.use_reference_core();
        }
        let link = e.add_resource("l", 100.0);
        e.flows.add_capacity_window(link, 0.0, 3.0, 0.0);
        let pool = e.add_pool(1);
        for i in 0..4u32 {
            // 25–100 bytes at 100 B/s: ≤ 1 s at full rate, so the
            // 1.5 s deadline only ever fires inside the blackout and
            // the retry budget (6) is never exhausted.
            let p = e.spawn(&format!("t{i}"), vec![
                Stage::Acquire(pool),
                Stage::Flow {
                    bytes: 25.0 + 25.0 * i as f64,
                    path: vec![link],
                    tag: i,
                    timeout: Some(SimNs::from_millis(1500)),
                },
                Stage::Release(pool),
            ]);
            e.set_flow_retry(
                p,
                SimNs::from_millis(500),
                SimNs::from_secs_f64(8.0),
                6,
            );
        }
        let end = e.run().unwrap();
        let log: Vec<(u32, u64, u64)> = e
            .flow_log
            .iter()
            .map(|f| (f.tag, f.start.as_nanos(), f.end.as_nanos()))
            .collect();
        (end, e.timeout_log.len(), log)
    };
    let a = build(false);
    let b = build(true);
    assert_eq!(a, b, "retry-through-blackout timeline diverged");
    assert!(a.1 > 0, "the scenario must actually exercise timeouts");
}
