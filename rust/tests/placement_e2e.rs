//! Pluggable-placement acceptance pins: locality- and cache-affinity
//! scheduling, end to end.
//!
//! * `HdfsLocal` lands every map on a node holding its split's live
//!   replica when replication covers the cluster — byte-weighted
//!   `locality_ratio == 1.0` — and degrades cleanly (job ok, bytes
//!   pinned, ratio < 1.0) when a DataNode is killed out from under it.
//! * `CacheAffinity` routes stage k+1 maps to the IGFS owners of
//!   stage k's handoff keys (stage-2 `locality_ratio == 1.0`,
//!   `affinity_hits` covers every hinted map), and under a cache-node
//!   blackout (PR 6) falls back down the tiers without moving a byte.
//! * Every strategy reproduces the FairOrder outputs bit-for-bit —
//!   placement moves tasks between nodes, never bytes.

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{
    output_key, run_job, stage_named_input, Cluster, JobPipeline,
    JobResult, PlacementStrategy, StoreKind, SystemConfig,
};
use marvel::net::{NetFaultPlan, NodeId};
use marvel::runtime::RtEngine;
use marvel::util::bytes::MIB;
use marvel::workloads::{PageRank, WordCount};

const SEED: u64 = 17;
const INPUT: u64 = 4 * MIB; // 16 splits at 256 KiB blocks
const NODES: usize = 4;
const SLOTS: usize = 8;

fn base_cfg(strategy: PlacementStrategy) -> SystemConfig {
    let mut c = SystemConfig::marvel_igfs();
    c.placement = strategy;
    c
}

fn deploy(cfg: &SystemConfig) -> Cluster {
    let mut cluster = ClusterSpec {
        nodes: NODES,
        slots_per_node: SLOTS,
        ..Default::default()
    }
    .deploy(cfg);
    cluster.stores.hdfs.block_size = 256 * 1024;
    cluster
}

/// Reducer outputs through the handoff chain: IGFS tiers, then HDFS.
fn outputs(
    cluster: &mut Cluster,
    job: &str,
    n: usize,
) -> Vec<Option<Vec<u8>>> {
    (0..n)
        .map(|j| {
            let key = output_key(job, j);
            if let Some((p, _)) =
                cluster.stores.igfs.get(&cluster.topo, NodeId(0), &key, 0)
            {
                return p.gather();
            }
            cluster
                .stores
                .hdfs
                .read(&cluster.topo, NodeId(0), &key, 0)
                .ok()
                .and_then(|(p, _, _, _)| p.gather())
        })
        .collect()
}

/// One wordcount run under `cfg`; returns the result and output bytes.
fn run_wc(cfg: &SystemConfig) -> (JobResult, Vec<Option<Vec<u8>>>) {
    let mut cluster = deploy(cfg);
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(2000, 1.07, &rt);
    let input =
        stage_named_input(&mut cluster, cfg, &wc, INPUT, SEED, "pl/in")
            .unwrap();
    let r = run_job(&mut cluster, cfg, &wc, &input, &mut rt, SEED);
    assert!(r.ok(), "job failed: {:?}", r.failed);
    let outs = outputs(&mut cluster, &r.job, r.reduce.tasks);
    (r, outs)
}

#[test]
fn hdfs_local_hits_full_locality_and_every_strategy_pins_bytes() {
    let (r0, o0) = run_wc(&base_cfg(PlacementStrategy::FairOrder));
    assert!(o0.iter().any(|o| o.as_ref().is_some_and(|b| !b.is_empty())));

    // Every replica is somewhere, and HdfsLocal refuses to run a map
    // off its split's replica set: all input bytes read node-local.
    let (rl, ol) = run_wc(&base_cfg(PlacementStrategy::HdfsLocal));
    assert_eq!(ol, o0, "HdfsLocal moved bytes");
    assert_eq!(
        rl.locality_ratio, 1.0,
        "replicas cover all splits => every map reads local, got {}",
        rl.locality_ratio
    );
    assert_eq!(
        rl.affinity_hits,
        rl.map.tasks as u64,
        "every map is hinted with its replica set and must land on it"
    );

    // The full strategy sweep: outputs are placement-invariant.
    for s in [
        PlacementStrategy::Random { seed: 7 },
        PlacementStrategy::RoundRobin,
        PlacementStrategy::CacheAffinity,
        PlacementStrategy::StragglerAware,
    ] {
        let (r, o) = run_wc(&base_cfg(s));
        assert_eq!(o, o0, "{} moved bytes", s.name());
        assert_eq!(r.output_bytes, r0.output_bytes, "{}", s.name());
        assert_eq!(
            r.intermediate_bytes, r0.intermediate_bytes,
            "{}",
            s.name()
        );
    }
}

#[test]
fn hdfs_local_degrades_cleanly_when_a_datanode_fails() {
    let (_, o0) = run_wc(&base_cfg(PlacementStrategy::FairOrder));

    // Two replicas per block, then kill DataNode 1 at plan time:
    // blocks whose primary lived there still place on the hint (the
    // compute node is alive — only its DataNode is gone), so their
    // reads fall back to the surviving replica remotely.
    let mut cfg = base_cfg(PlacementStrategy::HdfsLocal);
    cfg.replication = 2;
    cfg.failures.lose_datanodes = vec![1];
    let (r, o) = run_wc(&cfg);
    assert_eq!(o, o0, "a dead DataNode must never move bytes");
    assert!(
        r.locality_ratio < 1.0,
        "reads over the dead replica must go remote, got ratio {}",
        r.locality_ratio
    );
    assert!(
        r.locality_ratio > 0.0,
        "surviving primaries still serve their maps locally"
    );

    // Same failure without the strategy: bytes still pinned.
    let mut fair = base_cfg(PlacementStrategy::FairOrder);
    fair.replication = 2;
    fair.failures.lose_datanodes = vec![1];
    let (_, of) = run_wc(&fair);
    assert_eq!(of, o0);
}

/// Two-stage pipeline (wordcount seeding PageRank) with the handoff
/// riding the IGFS DRAM/PMEM tiers; returns the per-stage results and
/// the final outputs.
fn run_pipe(
    cfg: &SystemConfig,
) -> (Vec<JobResult>, Vec<Option<Vec<u8>>>) {
    let mut stage_cfg = cfg.clone();
    stage_cfg.output_store = StoreKind::Igfs;
    let mut cluster = deploy(cfg);
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(2000, 1.07, &rt);
    let pr = PageRank::new();
    let input = stage_named_input(
        &mut cluster, cfg, &wc, INPUT, SEED, "pipe/in",
    )
    .unwrap();
    let pipe = JobPipeline::new("pipe")
        .stage(&wc, stage_cfg.clone())
        .stage(&pr, stage_cfg.clone());
    let res = pipe.run(&mut cluster, &mut rt, SEED, &input);
    assert!(res.ok(), "pipeline failed: {:?}", res.failed);
    let last = res.stages.last().unwrap();
    let outs = outputs(&mut cluster, &last.job, last.reduce.tasks);
    (res.stages, outs)
}

#[test]
fn cache_affinity_routes_stage2_maps_to_handoff_owners() {
    let (fair, o0) = run_pipe(&base_cfg(PlacementStrategy::FairOrder));
    let (aff, oa) = run_pipe(&base_cfg(PlacementStrategy::CacheAffinity));
    assert_eq!(oa, o0, "CacheAffinity moved bytes");

    // Stage 2's splits are stage 1's IGFS-resident outputs; affinity
    // placement lands every hinted map on its key's owner, so every
    // handoff byte is read from local DRAM/PMEM.
    let s2 = &aff[1];
    assert_eq!(
        s2.locality_ratio, 1.0,
        "stage-2 maps must read their handoff keys on the owner, got {}",
        s2.locality_ratio
    );
    assert!(
        s2.affinity_hits >= s2.map.tasks as u64,
        "all {} hinted stage-2 maps must hit their owner (got {} hits)",
        s2.map.tasks,
        s2.affinity_hits
    );
    // The routing is real: affinity placement never hits fewer hinted
    // nodes than fair-share order does on the same stage.
    assert!(
        s2.affinity_hits >= fair[1].affinity_hits,
        "{} < {}",
        s2.affinity_hits,
        fair[1].affinity_hits
    );
}

#[test]
fn cache_affinity_falls_back_off_node_under_cache_blackout() {
    let (_, o0) = run_pipe(&base_cfg(PlacementStrategy::CacheAffinity));

    // Black out cache node 1 (PR 6): its DRAM/PMEM handoff copies are
    // lost between phases and gathers degrade down the tiers to the
    // HDFS write-through copies. Placement hints may still point at
    // the dead owner — the read path, not the scheduler, degrades.
    let mut cfg = base_cfg(PlacementStrategy::CacheAffinity);
    cfg.netfaults = NetFaultPlan {
        degraded_tiers: true,
        lose_cachenodes: vec![1],
        ..NetFaultPlan::disabled()
    };
    let (stages, o) = run_pipe(&cfg);
    assert_eq!(o, o0, "a cache blackout must never move bytes");
    assert!(
        stages.iter().any(|s| s.degraded_reads > 0),
        "node 1 owned handoff keys; some reads must degrade"
    );
}
