//! End-to-end recovery determinism: with any armed `FailurePlan`, a
//! job's *outputs* are byte-identical to the failure-free run — at any
//! `{map,reduce}_workers` setting and under a multi-tenant co-run.
//! Failures move only virtual time and attempt counts. Stateless
//! recovery recomputes strictly more bytes than stateful; an exhausted
//! retry budget surfaces as a job error, never a wrong answer; a lost
//! DataNode is transparent with replication and a job error without.
//!
//! The crash schedules derive from `MARVEL_FAILURE_SEED` (default 42)
//! via `SystemConfig::from_env`, which is how CI's determinism matrix
//! sweeps fault schedules: the byte-identity assertions here must hold
//! for *every* seed.

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{
    output_key, run_job, stage_input, stage_named_input, Cluster,
    JobResult, JobServer, StoreKind, SystemConfig,
};
use marvel::net::NodeId;
use marvel::runtime::RtEngine;
use marvel::util::bytes::MIB;
use marvel::workloads::WordCount;

const SEED: u64 = 11;
const INPUT: u64 = 4 * MIB;

/// Arm `cfg` with container-crash injection that always stays inside
/// the retry budget (max 2 crashes per task vs 3 attempts), over a
/// tight checkpoint interval so resumes are meaningful.
fn arm(cfg: &mut SystemConfig, crash_prob: f64) {
    cfg.failures.crash_prob = crash_prob;
    cfg.failures.max_failures_per_task = 2;
    cfg.recovery.max_attempts = 3;
    cfg.recovery.interval_bytes = 64 * 1024;
}

/// Every reducer's output bytes for `job`, read back through the
/// configured output store.
fn collect_outputs(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    job: &str,
    n_reduces: usize,
) -> Vec<Option<Vec<u8>>> {
    (0..n_reduces)
        .map(|j| {
            let key = output_key(job, j);
            let p = match cfg.output_store {
                StoreKind::Igfs => cluster
                    .stores
                    .igfs
                    .get(&cluster.topo, NodeId(0), &key, 0)
                    .map(|(p, _)| p),
                StoreKind::Hdfs => cluster
                    .stores
                    .hdfs
                    .read(&cluster.topo, NodeId(0), &key, 0)
                    .ok()
                    .map(|(p, _, _, _)| p),
                StoreKind::S3 => cluster.stores.s3.get(&key),
            };
            p.map(|p| p.gather().expect("real output"))
        })
        .collect()
}

/// Run one wordcount over 16 real splits on `nodes` nodes; return the
/// report plus every reducer's output bytes (empty when the job
/// failed before planning reducers).
fn run_wc(cfg: &SystemConfig, nodes: usize) -> (JobResult, Vec<Option<Vec<u8>>>) {
    let mut cluster = ClusterSpec::with_nodes(nodes).deploy(cfg);
    cluster.stores.hdfs.block_size = 256 * 1024;
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(4000, 1.07, &rt);
    let input = stage_input(&mut cluster, cfg, &wc, INPUT, SEED).unwrap();
    let r = run_job(&mut cluster, cfg, &wc, &input, &mut rt, SEED);
    let outs =
        collect_outputs(&mut cluster, cfg, &wc.name().to_string(), r.reduce.tasks);
    (r, outs)
}

#[test]
fn injected_failures_keep_outputs_byte_identical() {
    let base = SystemConfig::marvel_igfs();
    let (r0, o0) = run_wc(&base, 1);
    assert!(r0.ok(), "{:?}", r0.failed);
    assert!(r0.map.tasks > 1, "need real splits");
    assert_eq!(
        r0.task_attempts,
        (r0.map.tasks + r0.reduce.tasks) as u64,
        "failure-free: one attempt per task"
    );
    assert_eq!(r0.recomputed_bytes, 0);
    assert_eq!(r0.checkpoints, 0, "no plan armed, no checkpoint cost");
    assert!(o0.iter().any(|o| o.as_ref().is_some_and(|b| !b.is_empty())));

    for workers in [1usize, 4, 8] {
        let mut cfg = SystemConfig::marvel_igfs();
        cfg.map_workers = workers;
        cfg.reduce_workers = workers;
        arm(&mut cfg, 0.7);
        let (r, o) = run_wc(&cfg, 1);
        assert!(r.ok(), "workers={workers}: {:?}", r.failed);
        assert_eq!(o, o0, "outputs diverged at workers={workers}");
        assert_eq!(r.output_bytes, r0.output_bytes);
        assert_eq!(r.intermediate_bytes, r0.intermediate_bytes);
        assert_eq!(r.reduce.bytes_in, r0.reduce.bytes_in);
        // Attempts/bookkeeping may move; bytes may not. Stateful
        // checkpointing runs on every task once the plan is armed.
        assert!(
            r.task_attempts >= r0.task_attempts,
            "attempts can only grow: {} vs {}",
            r.task_attempts,
            r0.task_attempts
        );
        assert!(r.checkpoints > 0, "armed stateful plan checkpoints");
        assert!(r.checkpoint_overhead.as_nanos() > 0);
    }
}

#[test]
fn same_plan_same_schedule_same_times() {
    // The whole injected run is deterministic: identical config →
    // identical attempt counts, recomputed bytes, and virtual times.
    let run = || {
        let mut cfg = SystemConfig::marvel_igfs();
        arm(&mut cfg, 0.7);
        run_wc(&cfg, 1).0
    };
    let (a, b) = (run(), run());
    assert_eq!(a.task_attempts, b.task_attempts);
    assert_eq!(a.recomputed_bytes, b.recomputed_bytes);
    assert_eq!(a.job_time, b.job_time);
}

#[test]
fn stateless_recovery_recomputes_strictly_more() {
    // Fixed seed (explicit assignment wins over MARVEL_FAILURE_SEED):
    // every task crashes exactly once mid-split; stateful resumes from
    // a 32 KiB-interval checkpoint, stateless restarts from zero.
    let mk = |stateful: bool| {
        let mut cfg = SystemConfig::marvel_igfs();
        cfg.failures.crash_prob = 1.0;
        cfg.failures.max_failures_per_task = 1;
        cfg.failures.seed = 1337;
        cfg.recovery.max_attempts = 3;
        cfg.recovery.interval_bytes = 32 * 1024;
        cfg.recovery.stateful = stateful;
        run_wc(&cfg, 1)
    };
    let (st, so) = mk(true);
    let (sl, slo) = mk(false);
    assert!(st.ok(), "{:?}", st.failed);
    assert!(sl.ok(), "{:?}", sl.failed);
    assert_eq!(so, slo, "recovery mode changes work, never bytes");
    assert!(
        st.recomputed_bytes < sl.recomputed_bytes,
        "stateful {} must recompute less than stateless {}",
        st.recomputed_bytes,
        sl.recomputed_bytes
    );
    assert!(st.checkpoints > 0);
    assert_eq!(sl.checkpoints, 0, "stateless writes no checkpoints");
    assert_eq!(
        st.task_attempts, sl.task_attempts,
        "same crash schedule either way"
    );
}

#[test]
fn exhausted_retry_budget_is_a_job_error() {
    let mut cfg = SystemConfig::marvel_igfs();
    cfg.failures.crash_prob = 1.0;
    cfg.failures.max_failures_per_task = 10; // >= max_attempts: doomed
    cfg.failures.seed = 5;
    cfg.recovery.max_attempts = 3;
    cfg.recovery.interval_bytes = 64 * 1024;
    let (r, _) = run_wc(&cfg, 1);
    assert!(!r.ok(), "a task out of attempts must fail the job");
    let msg = r.failed.unwrap();
    assert!(
        msg.contains("retry budget exhausted"),
        "error names the budget: {msg}"
    );
}

#[test]
fn datanode_loss_is_transparent_with_replication() {
    // Failure-free baseline at the same shape (4 nodes, 2 replicas).
    let mut base = SystemConfig::marvel_igfs();
    base.replication = 2;
    let (r0, o0) = run_wc(&base, 4);
    assert!(r0.ok(), "{:?}", r0.failed);

    // Kill the writer-local DataNode (node 0 holds a replica of every
    // input block): reads fall back to survivors, bytes unchanged.
    let mut cfg = SystemConfig::marvel_igfs();
    cfg.replication = 2;
    cfg.failures.lose_datanodes = vec![0];
    let (r, o) = run_wc(&cfg, 4);
    assert!(r.ok(), "{:?}", r.failed);
    assert_eq!(o, o0, "surviving replicas serve identical bytes");
    assert_eq!(r.output_bytes, r0.output_bytes);

    // Without replication the sole replica dies with the node: the
    // job errors — it never fabricates an answer.
    let mut lone = SystemConfig::marvel_igfs();
    lone.replication = 1;
    lone.failures.lose_datanodes = vec![0];
    let (r, _) = run_wc(&lone, 4);
    assert!(!r.ok(), "sole-replica loss must be a job error");
    assert!(r.failed.unwrap().contains("no live replica"));

    // A typo'd node id must error, not silently run failure-free.
    let mut typo = SystemConfig::marvel_igfs();
    typo.failures.lose_datanodes = vec![9];
    let (r, _) = run_wc(&typo, 4);
    assert!(!r.ok(), "unknown DataNode id must fail the plan");
    assert!(r.failed.unwrap().contains("cluster has 4"));
}

#[test]
fn all_datanodes_lost_is_a_clean_job_error() {
    // Losing EVERY node must surface as a job error ("no live
    // replica"), never a panic. This is the end-to-end companion of
    // the PartitionMap last-member guard: with the whole cluster in
    // the failure plan, no layer may end up asking an empty membership
    // set for an owner.
    for nodes in [1usize, 4] {
        let mut cfg = SystemConfig::marvel_igfs();
        cfg.replication = 2;
        cfg.failures.lose_datanodes = (0..nodes).collect();
        let (r, _) = run_wc(&cfg, nodes);
        assert!(!r.ok(), "{nodes} nodes all lost must fail the job");
        assert!(
            r.failed.as_ref().unwrap().contains("no live replica"),
            "error names the data loss: {:?}",
            r.failed
        );
    }
    // The partition map itself refuses to go empty: the cache tier
    // keeps a total owner function even under the same plan.
    let mut cluster =
        ClusterSpec::with_nodes(2).deploy(&SystemConfig::marvel_igfs());
    assert_eq!(cluster.stores.igfs.partitions.remove(NodeId(0)), Ok(true));
    assert!(cluster.stores.igfs.partitions.remove(NodeId(1)).is_err());
    assert_eq!(cluster.stores.igfs.owner("any/key"), NodeId(1));
}

#[test]
fn corun_under_failures_matches_solo_outputs() {
    // Solo, failure-free reference.
    let (r0, o0) = run_wc(&SystemConfig::marvel_igfs(), 1);
    assert!(r0.ok(), "{:?}", r0.failed);

    // Two tenants co-run the same workload on one shared cluster with
    // crash injection armed: per-tenant outputs must match solo.
    let mut cfg = SystemConfig::marvel_igfs();
    cfg.map_workers = 2;
    cfg.reduce_workers = 2;
    arm(&mut cfg, 0.6);
    let mut cluster = ClusterSpec::default().deploy(&cfg);
    cluster.stores.hdfs.block_size = 256 * 1024;
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(4000, 1.07, &rt);
    let in_a = stage_named_input(&mut cluster, &cfg, &wc, INPUT, SEED,
                                 "alice/in")
        .unwrap();
    let in_b = stage_named_input(&mut cluster, &cfg, &wc, INPUT, SEED,
                                 "bob/in")
        .unwrap();
    let res = JobServer::new()
        .tenant("alice", 3)
        .tenant("bob", 1)
        .job("alice", &wc, cfg.clone(), &in_a, SEED)
        .job("bob", &wc, cfg.clone(), &in_b, SEED)
        .run(&mut cluster, &mut rt);
    assert!(res.ok(), "{:?}", res.failed);
    for run in &res.jobs {
        let jr = run.final_stage().unwrap();
        let outs =
            collect_outputs(&mut cluster, &cfg, &jr.job, jr.reduce.tasks);
        assert_eq!(outs, o0, "tenant {} diverged from solo", run.tenant);
    }
    // Attempt accounting rolls up per tenant.
    let attempts: u64 =
        res.tenants.iter().map(|t| t.task_attempts).sum();
    let tasks: u64 = res
        .jobs
        .iter()
        .flat_map(|j| &j.stages)
        .map(|s| (s.map.tasks + s.reduce.tasks) as u64)
        .sum();
    assert!(attempts >= tasks);
    // Checkpoint accounting rolls up per tenant too (armed stateful
    // plan → every tenant's tasks checkpointed).
    for t in &res.tenants {
        assert!(t.checkpoints > 0, "tenant {} wrote no checkpoints", t.name);
        assert!(t.checkpoint_overhead.as_nanos() > 0);
    }
}
