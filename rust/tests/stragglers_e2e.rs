//! Straggler + speculative-execution acceptance pins.
//!
//! * A nonzero `StragglerProfile` slows the job but never moves a
//!   byte: outputs are identical to the uniform-cluster run.
//! * With speculation enabled, outputs stay byte-identical to the
//!   speculation-off run at `{map,reduce}_workers ∈ {1, 4, 8}` under
//!   the same nonzero straggler profile — and the virtual makespan
//!   shrinks (backups on fast nodes win the race against 8× laggards).
//! * Speculation composes with an armed `FailurePlan`: crash recovery
//!   and backup races together still reproduce the baseline bytes,
//!   and the speculative scratch checkpoints are scrubbed.
//! * Under a multi-tenant co-run, per-tenant outputs still match solo.
//!
//! The straggler draw derives from `MARVEL_STRAGGLER_SEED` only for
//! profiles that don't pin `seed` explicitly; these tests pin it via
//! `mixed_seed()` so the cluster shape (one slow node, staging node
//! fast) is stable while CI's matrix sweeps the env seed through the
//! rest of the suite.

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{
    output_key, run_job, stage_named_input, Cluster, JobResult, JobServer,
    StoreKind, SystemConfig,
};
use marvel::net::{NodeId, StragglerProfile};
use marvel::runtime::RtEngine;
use marvel::util::bytes::MIB;
use marvel::workloads::WordCount;

const SEED: u64 = 13;
const INPUT: u64 = 8 * MIB;
const NODES: usize = 4;
const SLOTS: usize = 8;
const SLOWDOWN: f64 = 8.0;
const PROB: f64 = 0.4;

/// Straggler seed giving node 0 (the staging/locality node) full speed
/// and EXACTLY ONE slow node among the rest: a minority of tasks lag
/// the phase median — the shape speculation exists for. Deterministic:
/// `speed_of` is a pure function of `(seed, node)`.
fn mixed_seed() -> u64 {
    (0..50_000u64)
        .find(|&s| {
            let p = StragglerProfile {
                seed: s,
                prob: PROB,
                slowdown: SLOWDOWN,
            };
            let sp = p.speeds(NODES);
            sp[0] == 1.0
                && sp[1..].iter().filter(|v| **v < 1.0).count() == 1
        })
        .expect("a mixed straggler draw exists in 50k seeds")
}

fn cfg(stragglers: bool, speculation: bool, workers: usize) -> SystemConfig {
    let mut c = SystemConfig::marvel_igfs();
    c.map_workers = workers;
    c.reduce_workers = workers;
    if stragglers {
        c.stragglers = StragglerProfile {
            seed: mixed_seed(),
            prob: PROB,
            slowdown: SLOWDOWN,
        };
    }
    c.speculation.enabled = speculation;
    c
}

fn deploy(cfg: &SystemConfig) -> Cluster {
    let mut cluster = ClusterSpec {
        nodes: NODES,
        slots_per_node: SLOTS,
        ..Default::default()
    }
    .deploy(cfg);
    cluster.stores.hdfs.block_size = 256 * 1024; // 32 splits from 8 MiB
    cluster
}

/// Every reducer's output bytes for `job`, through the configured
/// output store.
fn collect_outputs(
    cluster: &mut Cluster,
    cfg: &SystemConfig,
    job: &str,
    n_reduces: usize,
) -> Vec<Option<Vec<u8>>> {
    (0..n_reduces)
        .map(|j| {
            let key = output_key(job, j);
            let p = match cfg.output_store {
                StoreKind::Igfs => cluster
                    .stores
                    .igfs
                    .get(&cluster.topo, NodeId(0), &key, 0)
                    .map(|(p, _)| p),
                StoreKind::Hdfs => cluster
                    .stores
                    .hdfs
                    .read(&cluster.topo, NodeId(0), &key, 0)
                    .ok()
                    .map(|(p, _, _, _)| p),
                StoreKind::S3 => cluster.stores.s3.get(&key),
            };
            p.map(|p| p.gather().expect("real output"))
        })
        .collect()
}

/// One wordcount over 32 real splits on the 4-node testbed; returns
/// the report, every reducer's bytes, and the cluster for post-mortems.
fn run_wc(cfg: &SystemConfig) -> (JobResult, Vec<Option<Vec<u8>>>, Cluster) {
    let mut cluster = deploy(cfg);
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(4000, 1.07, &rt);
    let input = stage_named_input(
        &mut cluster, cfg, &wc, INPUT, SEED, "wc/in",
    )
    .unwrap();
    let r = run_job(&mut cluster, cfg, &wc, &input, &mut rt, SEED);
    let outs = if r.ok() {
        collect_outputs(&mut cluster, cfg, &r.job, r.reduce.tasks)
    } else {
        Vec::new()
    };
    (r, outs, cluster)
}

#[test]
fn straggler_profile_moves_time_never_bytes() {
    let (r0, o0, _) = run_wc(&cfg(false, false, 1));
    assert!(r0.ok(), "{:?}", r0.failed);
    assert!(r0.map.tasks > 8, "need tasks spread past the local node");
    assert!(o0.iter().any(|o| o.as_ref().is_some_and(|b| !b.is_empty())));

    let (rs, os, _) = run_wc(&cfg(true, false, 1));
    assert!(rs.ok(), "{:?}", rs.failed);
    assert_eq!(os, o0, "a straggler profile must never move bytes");
    assert_eq!(rs.output_bytes, r0.output_bytes);
    assert_eq!(rs.intermediate_bytes, r0.intermediate_bytes);
    assert!(
        rs.job_time > r0.job_time,
        "an 8x straggler node must slow the job: {} vs {}",
        rs.job_time,
        r0.job_time
    );
    assert_eq!(rs.spec_backups, 0, "speculation off launches nothing");
    assert_eq!(
        rs.task_attempts,
        (rs.map.tasks + rs.reduce.tasks) as u64,
        "no failure plan, no speculation: one attempt per task"
    );
}

#[test]
fn speculation_keeps_bytes_identical_and_recovers_the_tail() {
    // Baseline: same straggler profile, speculation OFF.
    let (r_off, o_off, _) = run_wc(&cfg(true, false, 1));
    assert!(r_off.ok(), "{:?}", r_off.failed);

    for workers in [1usize, 4, 8] {
        let (r_on, o_on, _) = run_wc(&cfg(true, true, workers));
        assert!(r_on.ok(), "workers={workers}: {:?}", r_on.failed);
        assert_eq!(
            o_on, o_off,
            "outputs diverged with speculation on at workers={workers}"
        );
        assert_eq!(r_on.output_bytes, r_off.output_bytes);
        assert_eq!(r_on.intermediate_bytes, r_off.intermediate_bytes);
        assert_eq!(r_on.reduce.bytes_in, r_off.reduce.bytes_in);
        // The slow node hosts a minority of each phase's tasks, so
        // the planner must have backed some up — and the bookkeeping
        // must account every backup as an extra attempt.
        assert!(r_on.spec_backups > 0, "laggards must be backed up");
        assert!(
            r_on.spec_backup_wins >= 1,
            "a fast-node backup must beat an 8x-slowed original \
             at least once ({} backups)",
            r_on.spec_backups
        );
        assert!(r_on.spec_backup_wins <= r_on.spec_backups);
        assert_eq!(
            r_on.task_attempts,
            (r_on.map.tasks + r_on.reduce.tasks) as u64
                + r_on.spec_backups
        );
        // The point of the exercise: backups shorten the tail.
        assert!(
            r_on.job_time < r_off.job_time,
            "speculation must reduce makespan under stragglers: \
             on={} off={} (workers={workers})",
            r_on.job_time,
            r_off.job_time
        );
    }
    // Worker counts never change virtual time, with or without
    // speculation (the data plane is the only thing that fans out).
    let (r1, _, _) = run_wc(&cfg(true, true, 1));
    let (r8, _, _) = run_wc(&cfg(true, true, 8));
    assert_eq!(r1.job_time, r8.job_time);
    assert_eq!(r1.spec_backups, r8.spec_backups);
}

#[test]
fn speculation_composes_with_failure_injection() {
    let (_, o0, _) = run_wc(&cfg(false, false, 1));

    let mut c = cfg(true, true, 2);
    c.failures.crash_prob = 0.5;
    c.failures.max_failures_per_task = 2;
    c.failures.seed = 9;
    c.recovery.max_attempts = 3;
    c.recovery.interval_bytes = 64 * 1024;
    let (r, o, mut cluster) = run_wc(&c);
    assert!(r.ok(), "{:?}", r.failed);
    assert_eq!(o, o0, "speculation + crash recovery moved bytes");
    assert!(r.checkpoints > 0, "armed stateful plan checkpoints");
    assert!(r.spec_backups > 0, "stragglers still trigger backups");
    assert!(
        r.task_attempts
            > (r.map.tasks + r.reduce.tasks) as u64,
        "crashes and backups both add attempts"
    );
    // The speculative scratch checkpoints were scrubbed at plan time:
    // nothing under the job's spec/ prefix survives in any store or
    // the intermediate-length manifest.
    assert_eq!(
        cluster.stores.clear_prefix(&format!("{}/spec/", r.job)),
        0,
        "speculative scratch keys must already be scrubbed"
    );
}

#[test]
fn speculation_under_corun_matches_solo() {
    let (_, o0, _) = run_wc(&cfg(false, false, 1));

    let base = cfg(true, true, 2);
    let mut cluster = deploy(&base);
    let mut rt = RtEngine::load(None).unwrap();
    let wc = WordCount::new(4000, 1.07, &rt);
    let in_a = stage_named_input(&mut cluster, &base, &wc, INPUT, SEED,
                                 "alice/in")
        .unwrap();
    let in_b = stage_named_input(&mut cluster, &base, &wc, INPUT, SEED,
                                 "bob/in")
        .unwrap();
    let res = JobServer::new()
        .tenant("alice", 3)
        .tenant("bob", 1)
        .job("alice", &wc, base.clone(), &in_a, SEED)
        .job("bob", &wc, base.clone(), &in_b, SEED)
        .run(&mut cluster, &mut rt);
    assert!(res.ok(), "{:?}", res.failed);
    for run in &res.jobs {
        let jr = run.final_stage().unwrap();
        let outs =
            collect_outputs(&mut cluster, &base, &jr.job, jr.reduce.tasks);
        assert_eq!(outs, o0, "tenant {} diverged from solo", run.tenant);
    }
    // Backups are charged to their tenant's class and roll up into
    // the per-tenant reports; each race resolved exactly one loser.
    let total_backups: u64 =
        res.tenants.iter().map(|t| t.spec_backups).sum();
    assert!(total_backups > 0, "co-run stragglers must speculate");
    for t in &res.tenants {
        assert!(t.spec_backup_wins <= t.spec_backups, "{}", t.name);
    }
    for s in res.jobs.iter().flat_map(|j| &j.stages) {
        assert!(s.spec_backup_wins <= s.spec_backups, "{}", s.job);
    }
}
