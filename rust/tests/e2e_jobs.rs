//! Integration: every workload × every system configuration on small
//! *real* inputs — the full Figure-3 workflow, end to end.

use marvel::coordinator::{ClusterSpec, Marvel};
use marvel::mapreduce::{JobResult, SystemConfig, Workload};
use marvel::util::bytes::MIB;
use marvel::workloads::{
    AggregationQuery, Corpus, Grep, JoinQuery, ScanQuery, WordCount,
};

fn all_configs() -> Vec<SystemConfig> {
    use marvel::net::DeviceRole;
    vec![
        SystemConfig::corral_lambda(),
        SystemConfig::marvel_hdfs(),
        SystemConfig::marvel_igfs(),
        SystemConfig::onprem(DeviceRole::Pmem, false),
        SystemConfig::onprem(DeviceRole::Pmem, true),
        SystemConfig::onprem(DeviceRole::Ssd, false),
        SystemConfig::onprem(DeviceRole::Ssd, true),
    ]
}

fn check(r: &JobResult) {
    assert!(r.ok(), "{} on {}: {:?}", r.job, r.config, r.failed);
    assert!(r.job_time.as_secs_f64() > 0.0);
    assert!(r.input_bytes > 0);
    assert!(r.intermediate_bytes > 0, "{} {}", r.job, r.config);
    assert!(r.output_bytes > 0, "{} {}", r.job, r.config);
    assert!(r.map.tasks > 0 && r.reduce.tasks > 0);
    assert!(r.io.total_bytes > 0.0);
}

#[test]
fn wordcount_all_systems() {
    let mut m = Marvel::new(ClusterSpec::default(), 1).unwrap();
    let wc = WordCount::new(3000, 1.07, &m.rt);
    for cfg in all_configs() {
        check(&m.run(&cfg, &wc, 3 * MIB));
    }
}

#[test]
fn grep_all_systems() {
    let mut m = Marvel::new(ClusterSpec::default(), 2).unwrap();
    let prefix = Corpus::new(3000, 1.07).prefix_of_rank(2, 2);
    let g = Grep::new(3000, 1.07, &prefix, &m.rt);
    for cfg in all_configs() {
        check(&m.run(&cfg, &g, 3 * MIB));
    }
}

#[test]
fn queries_on_marvel_and_lambda() {
    let mut m = Marvel::new(ClusterSpec::default(), 3).unwrap();
    let agg = AggregationQuery::new(&m.rt);
    let wls: Vec<Box<dyn Workload>> = vec![
        Box::new(ScanQuery::new()),
        Box::new(JoinQuery::new()),
    ];
    for cfg in [SystemConfig::corral_lambda(), SystemConfig::marvel_igfs()] {
        check(&m.run(&cfg, &agg, 3 * MIB));
        for wl in &wls {
            check(&m.run(&cfg, wl.as_ref(), 3 * MIB));
        }
    }
}

#[test]
fn multi_node_cluster_runs_and_uses_locality() {
    let mut m = Marvel::new(ClusterSpec::with_nodes(4), 4).unwrap();
    let wc = WordCount::new(3000, 1.07, &m.rt);
    let mut cfg = SystemConfig::marvel_hdfs();
    cfg.replication = 2;
    // This pin is about the *legacy* replica-pref scan, so hold the
    // strategy fixed — the CI determinism matrix sweeps
    // MARVEL_PLACEMENT, and a random-placement leg would read mostly
    // remote by design (rust/tests/placement_e2e.rs covers that axis).
    cfg.placement = marvel::mapreduce::PlacementStrategy::FairOrder;
    let r = m.run(&cfg, &wc, 8 * MIB);
    check(&r);
    // All input blocks written from node 0 with first-replica-local
    // placement → map tasks should read mostly locally.
    assert!(r.locality_ratio > 0.5, "locality {}", r.locality_ratio);
}

#[test]
fn ordering_holds_on_medium_synthetic_input() {
    // 2 GB synthetic: the Figure-4 ordering must hold well above the
    // materialization cap.
    let mut m = Marvel::new(ClusterSpec::default(), 5).unwrap();
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let r = m.compare(
        &[
            SystemConfig::corral_lambda(),
            SystemConfig::marvel_hdfs(),
            SystemConfig::marvel_igfs(),
        ],
        &wc,
        2_000_000_000,
    );
    for x in &r {
        assert!(x.ok(), "{}: {:?}", x.config, x.failed);
    }
    assert!(r[0].job_time > r[1].job_time, "lambda must lose to hdfs");
    assert!(r[1].job_time >= r[2].job_time, "igfs must not lose to hdfs");
}

#[test]
fn job_reports_are_internally_consistent() {
    let mut m = Marvel::new(ClusterSpec::default(), 6).unwrap();
    let wc = WordCount::new(3000, 1.07, &m.rt);
    let r = m.run(&SystemConfig::marvel_igfs(), &wc, 4 * MIB);
    check(&r);
    // Phases partition the makespan.
    let total = r.map.duration + r.reduce.duration;
    assert_eq!(total, r.job_time);
    // Reduce consumed exactly what maps produced.
    assert_eq!(r.map.bytes_out, r.intermediate_bytes);
    assert_eq!(r.reduce.bytes_in, r.intermediate_bytes);
    assert_eq!(r.reduce.bytes_out, r.output_bytes);
}
