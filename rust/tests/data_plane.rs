//! Real-vs-synthetic data plane cross-validation: the same job run just
//! below and just above the materialization cap must report nearly
//! identical byte accounting and virtual times (ARCHITECTURE.md,
//! Two-plane execution model).

use marvel::coordinator::{ClusterSpec, Marvel};
use marvel::mapreduce::{SystemConfig, Workload};
use marvel::util::bytes::MIB;
use marvel::workloads::{
    AggregationQuery, Corpus, Grep, JoinQuery, ScanQuery, WordCount,
};

/// Run `wl` at the same size with materialization forced on/off by
/// moving the cap, and compare accounting.
fn cross_validate(wl: &dyn Workload, cfg_base: &SystemConfig, tol: f64) {
    let bytes = 8 * MIB;
    let run = |materialize: bool| {
        let mut m = Marvel::new(ClusterSpec::default(), 77).unwrap();
        let mut cfg = cfg_base.clone();
        cfg.materialize_cap = if materialize { 16 * MIB } else { 0 };
        let r = m.run(&cfg, wl, bytes);
        assert!(r.ok(), "{}: {:?}", cfg.name, r.failed);
        r
    };
    let real = run(true);
    let synth = run(false);
    let rel = |a: u64, b: u64| -> f64 {
        if a == 0 && b == 0 {
            return 0.0;
        }
        (a as f64 - b as f64).abs() / (a.max(b) as f64)
    };
    assert!(
        rel(real.intermediate_bytes, synth.intermediate_bytes) < tol,
        "{}: intermediate real {} vs synth {}",
        wl.name(), real.intermediate_bytes, synth.intermediate_bytes
    );
    assert!(
        rel(real.output_bytes, synth.output_bytes) < 0.5,
        "{}: output real {} vs synth {}",
        wl.name(), real.output_bytes, synth.output_bytes
    );
    let t_rel = (real.job_time.as_secs_f64() - synth.job_time.as_secs_f64())
        .abs()
        / real.job_time.as_secs_f64();
    assert!(t_rel < tol,
            "{}: time real {} vs synth {}", wl.name(), real.job_time,
            synth.job_time);
}

#[test]
fn wordcount_raw_modes_agree() {
    let wc = {
        let m = Marvel::new(ClusterSpec::default(), 1).unwrap();
        WordCount::new(10_000, 1.07, &m.rt)
    };
    cross_validate(&wc, &SystemConfig::corral_lambda(), 0.10);
}

#[test]
fn wordcount_kernel_modes_agree() {
    let wc = {
        let m = Marvel::new(ClusterSpec::default(), 1).unwrap();
        WordCount::new(10_000, 1.07, &m.rt)
    };
    // Kernel aggregates: synthetic assumes full vocab coverage; at 8 MiB
    // real coverage is slightly below — allow a wider band.
    cross_validate(&wc, &SystemConfig::marvel_igfs(), 0.25);
}

#[test]
fn grep_modes_agree() {
    let g = {
        let m = Marvel::new(ClusterSpec::default(), 1).unwrap();
        let prefix = Corpus::new(10_000, 1.07).prefix_of_rank(3, 2);
        Grep::new(10_000, 1.07, &prefix, &m.rt)
    };
    cross_validate(&g, &SystemConfig::marvel_igfs(), 0.35);
}

#[test]
fn scan_modes_agree() {
    cross_validate(&ScanQuery::new(), &SystemConfig::corral_lambda(), 0.15);
}

#[test]
fn agg_modes_agree() {
    let agg = {
        let m = Marvel::new(ClusterSpec::default(), 1).unwrap();
        AggregationQuery::new(&m.rt)
    };
    cross_validate(&agg, &SystemConfig::corral_lambda(), 0.15);
}

#[test]
fn join_modes_agree() {
    cross_validate(&JoinQuery::new(), &SystemConfig::corral_lambda(), 0.15);
}
