#!/usr/bin/env python3
"""Warn-only diff of two BENCH_*.json reports (baseline vs current).

Prints a per-metric and per-result delta table. Exits 1 if any
throughput-style metric regressed by more than THRESHOLD so the CI step
can raise a warning annotation; the workflow treats that as non-fatal.
"""
import json
import sys

THRESHOLD = 0.15  # 15% regression tolerance — bench runners are noisy

# Metrics where bigger is better ("*_per_s", "*_speedup") — the
# events/sec engine lane and the data-plane rates; everything else
# (latencies, "*_ns") is smaller-is-better.
def bigger_is_better(name: str) -> bool:
    return name.endswith("_per_s") or name.endswith("_speedup")


# Run-shape descriptors (task counts, worker counts) recorded for
# context: diffed for visibility but never flagged as regressions.
def is_config(name: str) -> bool:
    return name.endswith("_tasks") or name.endswith("_workers")


def direction(name: str) -> str:
    if is_config(name):
        return "·"
    return "↑" if bigger_is_better(name) else "↓"


def main() -> int:
    base_path, cur_path = sys.argv[1], sys.argv[2]
    with open(base_path) as f:
        base = json.load(f)
    with open(cur_path) as f:
        cur = json.load(f)

    regressed = []
    print(f"{'metric':<40} {'dir':>3} {'baseline':>14} {'current':>14}"
          f" {'delta':>9}")
    for name, b in sorted(base.get("metrics", {}).items()):
        c = cur.get("metrics", {}).get(name)
        if c is None or not b:
            continue
        delta = (c - b) / abs(b)
        mark = ""
        if not is_config(name):
            bad = -delta if bigger_is_better(name) else delta
            if bad > THRESHOLD:
                mark = "  << REGRESSED"
                regressed.append(name)
        print(f"{name:<40} {direction(name):>3} {b:>14.2f} {c:>14.2f}"
              f" {delta:>8.1%}{mark}")

    print()
    print(f"{'bench (mean ns)':<55} {'baseline':>12} {'current':>12}")
    for name, b in sorted(base.get("results", {}).items()):
        c = cur.get("results", {}).get(name)
        if c is None:
            continue
        print(f"{name:<55} {b['mean_ns']:>12.0f} {c['mean_ns']:>12.0f}")

    if regressed:
        print(f"\nregressed >{THRESHOLD:.0%}: {', '.join(regressed)}")
        return 1
    print("\nno metric regressed beyond tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
