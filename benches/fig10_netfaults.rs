//! Figure 10 (repo extension): degraded-mode I/O — network fault
//! injection × graceful storage-tier degradation.
//!
//! One wordcount runs on a 4-node cluster while a seed-driven
//! `NetFaultPlan` degrades links (slowdown or blackout windows) and —
//! for nonzero fault probabilities — blacks out cache node 1 between
//! the map and reduce phases. The sweep is fault probability ×
//! degraded-tiers {off, on}. Reported per cell: whether the job
//! completed, virtual makespan, flow-deadline expiries (each one a
//! reaped + retried transfer), and reads served from a lower tier.
//!
//! Expected shape — the graceful-degradation contract: with
//! `degraded_tiers` ON every cell completes with byte-identical
//! output (blackout gathers fall down to the HDFS write-through
//! copies and pay the slower tier in virtual time); with it OFF the
//! blackout cells FAIL outright (the manifest reports the sole cache
//! copy lost). Cold starts are forced so task flows land inside the
//! fault-window band instead of racing ahead of it. Emits
//! `BENCH_fig10_netfaults.json` via `util::bench::write_report` for
//! `bench_diff.py`.

use std::path::Path;

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{run_job, stage_named_input, SystemConfig};
use marvel::net::NetFaultPlan;
use marvel::runtime::RtEngine;
use marvel::sim::SimNs;
use marvel::util::bench::{write_report, Bench, BenchResult};
use marvel::util::bytes::MIB;
use marvel::workloads::WordCount;

const SEED: u64 = 42;
const NETFAULT_SEED: u64 = 29;
const INPUT: u64 = 8 * MIB;
const NODES: usize = 4;
const SLOTS: usize = 8;

fn cfg_for(prob: f64, degraded: bool) -> SystemConfig {
    let mut c = SystemConfig::marvel_igfs();
    c.map_workers = 2;
    c.reduce_workers = 2;
    // Cold starts push task flows into the fault-window band — a
    // prewarmed 8 MiB job races ahead of the earliest window.
    c.prewarm = false;
    c.netfaults = NetFaultPlan {
        seed: NETFAULT_SEED,
        prob,
        slowdown: 8.0,
        flow_timeout: SimNs::from_millis(250),
        degraded_tiers: degraded,
        // A fault scenario = degraded links + one cache node dark.
        lose_cachenodes: if prob > 0.0 { vec![1] } else { vec![] },
    };
    c
}

struct Cell {
    completed: bool,
    makespan_s: f64,
    flow_timeouts: u64,
    degraded_reads: u64,
    output_bytes: u64,
}

fn run_cell(cfg: &SystemConfig) -> Cell {
    let mut rt = RtEngine::load(None).expect("rt");
    // Deploy + stage over a healthy network, then install the fault
    // windows: faults strike mid-run, not mid-staging.
    let mut quiet = cfg.clone();
    quiet.netfaults = NetFaultPlan::disabled();
    let mut cluster = ClusterSpec {
        nodes: NODES,
        slots_per_node: SLOTS,
        ..Default::default()
    }
    .deploy(&quiet);
    cluster.stores.hdfs.block_size = 256 * 1024; // 32 splits from 8 MiB
    let wc = WordCount::new(10_000, 1.07, &rt);
    let input =
        stage_named_input(&mut cluster, cfg, &wc, INPUT, SEED, "wc/in")
            .expect("stage");
    cfg.netfaults.install(&cluster.topo, &mut cluster.engine);
    let r = run_job(&mut cluster, cfg, &wc, &input, &mut rt, SEED);
    Cell {
        completed: r.ok(),
        makespan_s: if r.ok() { r.job_time.as_secs_f64() } else { 0.0 },
        flow_timeouts: r.flow_timeouts,
        degraded_reads: r.degraded_reads,
        output_bytes: r.output_bytes,
    }
}

fn main() {
    let bench = Bench::new(1, 3);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut metrics: Vec<(String, f64)> = Vec::new();

    let mut baseline_output = None;
    let mut baseline_makespan = None;
    for &prob in &[0.0f64, 0.3, 0.6, 1.0] {
        for degraded in [false, true] {
            let mode = if degraded { "deg-on" } else { "deg-off" };
            let cfg = cfg_for(prob, degraded);
            let mut cell = None;
            let r = bench.run(
                &format!("wordcount 8 MiB, fault-prob={prob}, {mode}"),
                || {
                    let c = run_cell(&cfg);
                    let out = c.output_bytes;
                    cell = Some(c);
                    out
                },
            );
            println!("{}", r.summary());
            let cell = cell.expect("bench ran");
            println!(
                "  {mode} p={prob}: completed={}, {:.3} virtual s, \
                 {} flow timeouts, {} degraded reads",
                cell.completed, cell.makespan_s, cell.flow_timeouts,
                cell.degraded_reads,
            );

            // The fig10 contract, asserted per cell.
            if prob == 0.0 {
                assert!(cell.completed, "fault-free cell must complete");
                assert_eq!(cell.flow_timeouts, 0, "no plan, no deadlines");
                assert_eq!(cell.degraded_reads, 0);
                baseline_makespan.get_or_insert(cell.makespan_s);
            } else if degraded {
                assert!(
                    cell.completed,
                    "graceful degradation must ride out the blackout \
                     at p={prob}"
                );
                assert!(
                    cell.degraded_reads > 0,
                    "blackout gathers must fall down the tiers at \
                     p={prob}"
                );
                assert!(
                    cell.makespan_s
                        > baseline_makespan.expect("baseline ran"),
                    "degraded tiers are not free at p={prob}"
                );
            } else {
                assert!(
                    !cell.completed,
                    "blackout without degradation must fail at p={prob}"
                );
            }
            // Byte determinism across every completing cell.
            if cell.completed {
                match baseline_output {
                    None => baseline_output = Some(cell.output_bytes),
                    Some(b) => assert_eq!(
                        cell.output_bytes, b,
                        "fault plan moved bytes at p={prob} {mode}"
                    ),
                }
            }

            let tag = format!("p{:03}_{mode}", (prob * 100.0) as u32);
            metrics.push((format!("{tag}_completed"),
                          if cell.completed { 1.0 } else { 0.0 }));
            metrics.push((format!("{tag}_virtual_makespan_s"),
                          cell.makespan_s));
            metrics.push((format!("{tag}_flow_timeouts"),
                          cell.flow_timeouts as f64));
            metrics.push((format!("{tag}_degraded_reads"),
                          cell.degraded_reads as f64));
            results.push(r);
        }
    }

    let refs: Vec<&BenchResult> = results.iter().collect();
    let met: Vec<(&str, f64)> =
        metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let out = Path::new("BENCH_fig10_netfaults.json");
    match write_report(out, &refs, &met) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
    println!("fig10_netfaults done");
}
