//! DES engine throughput (§Perf, engine-side lane): events/second on a
//! synthetic multi-tenant job — a million tasks by default — with
//! everything the serving stack throws at the hot path at once: slot
//! pools under weighted-fair contention, two-hop flows over a shared
//! fabric, per-wave barriers, and speculative Cancel races. A second
//! lane replays a scaled-down copy of the same job through the retained
//! naive reference core (binary-heap timers + full flow re-rates) to
//! report the wheel/arena speedup, and a third stresses incremental
//! flow re-rating with staggered churn on a hub link.
//!
//! Emits `BENCH_engine_throughput.json` (read by PERF.md's trajectory;
//! `engine_events_per_s` and `*_speedup` are higher-is-better in
//! bench_diff). `MARVEL_ENGINE_TASKS` overrides the task count.

use std::path::Path;

use marvel::sim::{Engine, SimNs, Stage};
use marvel::util::bench::{write_report, Bench, BenchResult};
use marvel::util::rng::Rng;

const NODES: usize = 32;
const SLOTS_PER_NODE: usize = 8;
const TENANTS: u32 = 4;
const WAVE: usize = 4096;
const SPEC_EVERY: usize = 64;

/// Build the synthetic job and return `(engine, ops_built)`. `ops_built`
/// counts compiled stage ops — the event-throughput denominator (a few
/// speculation losers skip their tails; the undercount is < 2%).
fn build_job(n_tasks: usize, reference_core: bool) -> (Engine, u64) {
    let mut e = Engine::new();
    if reference_core {
        e.use_reference_core();
    }
    for c in 0..TENANTS {
        e.set_class_weight(c, (c + 1) as u64);
    }
    let nics: Vec<_> = (0..NODES)
        .map(|i| e.add_resource(&format!("nic{i}"), 1e9))
        .collect();
    let pools: Vec<_> =
        (0..NODES).map(|_| e.add_pool(SLOTS_PER_NODE)).collect();
    let n_waves = (n_tasks + WAVE - 1) / WAVE;
    let bars: Vec<_> = (0..n_waves)
        .map(|w| {
            let in_wave = WAVE.min(n_tasks - w * WAVE);
            e.add_barrier(in_wave)
        })
        .collect();
    let mut rng = Rng::new(0xE49E);
    let mut ops = 0u64;
    for i in 0..n_tasks {
        let wave = i / WAVE;
        let class = (i as u32) % TENANTS;
        let src = rng.below(NODES as u64) as usize;
        let dst = (src + 1 + rng.below((NODES - 1) as u64) as usize) % NODES;
        let mut stages = Vec::with_capacity(7);
        if wave > 0 {
            stages.push(Stage::Await(bars[wave - 1]));
        }
        stages.push(Stage::Acquire(pools[src]));
        stages.push(Stage::Delay(SimNs::from_micros(rng.range(50, 5000))));
        stages.push(Stage::Flow {
            bytes: 1e4 + rng.below(1_000_000) as f64,
            path: vec![nics[src], nics[dst]],
            tag: class,
            // A generous deadline on some flows keeps the deadline
            // scan hot without ever firing it.
            timeout: if i % 97 == 0 {
                Some(SimNs::from_secs_f64(3600.0))
            } else {
                None
            },
        });
        stages.push(Stage::Release(pools[src]));
        if i % SPEC_EVERY == 0 {
            // Speculative race: the original's tail is appended after
            // the backup exists (the non-contiguous arena path), each
            // racer cancels the other, the winner arrives.
            ops += stages.len() as u64;
            let orig =
                e.spawn_as(&format!("t{i:07}"), class, stages.clone());
            let mut bak = stages;
            // The backup skips the flow: a short straggler-dodge copy.
            bak.truncate(if wave > 0 { 2 } else { 1 });
            bak.push(Stage::Delay(SimNs::from_micros(rng.range(10, 500))));
            bak.push(Stage::Release(pools[src]));
            bak.push(Stage::Cancel(orig));
            bak.push(Stage::Arrive(bars[wave]));
            ops += bak.len() as u64;
            let bak_id = e.spawn_as(&format!("t{i:07}/bak"), class, bak);
            e.append_stages(
                orig,
                vec![Stage::Cancel(bak_id), Stage::Arrive(bars[wave])],
            );
            ops += 2;
        } else {
            stages.push(Stage::Arrive(bars[wave]));
            ops += stages.len() as u64;
            e.spawn_as(&format!("t{i:07}"), class, stages);
        }
    }
    (e, ops)
}

fn main() {
    let n_tasks: usize = std::env::var("MARVEL_ENGINE_TASKS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut metrics: Vec<(&str, f64)> = Vec::new();

    // -- lane 1: the full job on the production core (timing wheel +
    // arenas + incremental re-rate).
    let bench = Bench::new(1, 3);
    let (_, ops) = build_job(1, false); // warm nothing; just shape check
    assert!(ops > 0);
    let mut ends = Vec::new();
    let label = format!("engine: {n_tasks} tasks, flows+barriers+spec");
    let mut total_ops = 0u64;
    let r_big = bench.run(&label, || {
        let (mut e, ops) = build_job(n_tasks, false);
        total_ops = ops;
        let end = e.run().unwrap();
        ends.push(end);
        end
    });
    assert!(
        ends.windows(2).all(|w| w[0] == w[1]),
        "engine end time must be identical across runs"
    );
    println!("{}", r_big.summary());
    let ev_s = r_big.throughput(total_ops as f64);
    println!(
        "  {total_ops} events/iter → {:.2} M events/s (virtual end {})",
        ev_s / 1e6,
        ends[0],
    );
    results.push(r_big);
    metrics.push(("engine_events_per_s", ev_s));
    metrics.push(("engine_tasks", n_tasks as f64));

    // -- lane 2: wheel/arena core vs the retained naive reference core
    // on a scaled-down copy (the reference heap is the old hot path).
    // Also a differential smoke check: both cores must agree on the
    // virtual end time exactly.
    let n_ref = (n_tasks / 10).clamp(1, 100_000);
    let bench_ref = Bench::new(1, 3);
    let r_wheel = bench_ref.run(&format!("wheel core: {n_ref} tasks"), || {
        let (mut e, _) = build_job(n_ref, false);
        e.run().unwrap()
    });
    let r_refc =
        bench_ref.run(&format!("reference core: {n_ref} tasks"), || {
            let (mut e, _) = build_job(n_ref, true);
            e.run().unwrap()
        });
    let (mut ew, _) = build_job(n_ref, false);
    let (mut er, _) = build_job(n_ref, true);
    assert_eq!(
        ew.run().unwrap(),
        er.run().unwrap(),
        "wheel and reference cores diverged"
    );
    println!("{}", r_wheel.summary());
    println!("{}", r_refc.summary());
    let speedup = r_refc.mean_ns / r_wheel.mean_ns.max(1.0);
    println!("  wheel vs reference: {speedup:.2}× (identical end times ✓)");
    results.push(r_wheel);
    results.push(r_refc);
    metrics.push(("wheel_vs_reference_speedup", speedup));

    // -- lane 3: flow-plane churn — staggered starts/completions on
    // two-hop paths through one hub link, so every event re-rates a
    // live component while most of the fabric stays untouched.
    let bench_churn = Bench::new(1, 5);
    let n_flows = 2048u64;
    let r_churn = bench_churn.run("flow churn: 2048 staggered 2-hop", || {
        let mut e = Engine::new();
        let hub = e.add_resource("hub", 1e10);
        let spokes: Vec<_> = (0..NODES)
            .map(|i| e.add_resource(&format!("s{i}"), 1e9))
            .collect();
        for i in 0..n_flows {
            let s = spokes[(i as usize) % NODES];
            e.spawn(&format!("f{i:04}"), vec![
                Stage::Delay(SimNs::from_micros(i * 37)),
                Stage::Flow {
                    bytes: 1e6,
                    path: vec![s, hub],
                    tag: 0,
                    timeout: None,
                },
            ]);
        }
        e.run().unwrap()
    });
    println!("{}", r_churn.summary());
    let churn_s = r_churn.throughput(n_flows as f64);
    println!("  {:.1}k flow completions/s", churn_s / 1e3);
    results.push(r_churn);
    metrics.push(("flow_churn_per_s", churn_s));

    let refs: Vec<&BenchResult> = results.iter().collect();
    let out = Path::new("BENCH_engine_throughput.json");
    match write_report(out, &refs, &metrics) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
    println!("engine_throughput done");
}
