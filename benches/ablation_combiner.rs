//! Ablation: the L1 kernel combiner's contribution. Same system
//! (Marvel-IGFS), combiner on vs off — isolates how much of Marvel's
//! win comes from shipping aggregates instead of raw records.

use marvel::coordinator::{ClusterSpec, Marvel};
use marvel::mapreduce::{CombinerMode, SystemConfig};
use marvel::util::bytes;
use marvel::util::table::{fmt_pct, fmt_secs, Table};
use marvel::workloads::WordCount;

const GB: u64 = 1_000_000_000;

fn main() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).expect("marvel");
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let with = SystemConfig::marvel_igfs();
    let mut without = SystemConfig::marvel_igfs();
    without.combiner = CombinerMode::None;
    without.name = "marvel-igfs/no-combine".into();

    let mut t = Table::new(
        "Ablation — kernel combiner (WordCount, Marvel-IGFS)",
        &["input (GB)", "combine: time", "intermediate",
          "no-combine: time", "intermediate", "speedup"],
    );
    for gb in [1.0f64, 5.0, 10.0, 20.0] {
        let bytes_in = (gb * GB as f64) as u64;
        let a = m.run(&with, &wc, bytes_in);
        let b = m.run(&without, &wc, bytes_in);
        assert!(a.ok() && b.ok());
        t.row(&[
            format!("{gb}"),
            fmt_secs(a.job_time.as_secs_f64()),
            bytes::human(a.intermediate_bytes),
            fmt_secs(b.job_time.as_secs_f64()),
            bytes::human(b.intermediate_bytes),
            fmt_pct(1.0 - a.job_time.as_secs_f64()
                    / b.job_time.as_secs_f64()),
        ]);
        assert!(a.intermediate_bytes * 10 < b.intermediate_bytes,
                "combiner must shrink intermediate >10x at {gb} GB");
        assert!(a.job_time <= b.job_time,
                "combiner must not slow the job at {gb} GB");
    }
    t.print();
    println!("ablation_combiner OK");
}
