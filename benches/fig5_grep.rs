//! Regenerates **Figure 5**: Grep execution time vs input size across
//! the three systems. Same shape expectations as Figure 4; Grep's
//! intermediate volume is far smaller, so the gap narrows at the small
//! end (cold-start/startup dominated) and is I/O-driven at the big end.

use marvel::coordinator::{reduction, ClusterSpec, Marvel};
use marvel::mapreduce::SystemConfig;
use marvel::util::table::{fmt_pct, fmt_secs, Table};
use marvel::workloads::{Corpus, Grep};

const GB: u64 = 1_000_000_000;

fn main() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).expect("marvel");
    let prefix = Corpus::new(10_000, 1.07).prefix_of_rank(5, 2);
    let grep = Grep::new(10_000, 1.07, &prefix, &m.rt);
    println!("pattern prefix: {:?} (match prob {:.3})",
             String::from_utf8_lossy(&prefix), grep.match_prob());

    let sizes_gb = [0.5f64, 1.0, 2.0, 5.0, 10.0, 15.0, 20.0, 50.0];
    let configs = [
        SystemConfig::corral_lambda(),
        SystemConfig::marvel_hdfs_paper(),
        SystemConfig::marvel_igfs_paper(),
    ];
    let mut t = Table::new(
        "Figure 5 — Grep execution time (s)",
        &["input (GB)", "lambda-s3", "marvel-hdfs", "marvel-igfs",
          "reduction vs lambda"],
    );
    let mut best: f64 = 0.0;
    for gb in sizes_gb {
        let results = m.compare(&configs, &grep, (gb * GB as f64) as u64);
        let lam = &results[0];
        let igfs = &results[2];
        t.row(&[
            format!("{gb}"),
            if lam.ok() { fmt_secs(lam.job_time.as_secs_f64()) }
            else { "FAIL (quota)".into() },
            fmt_secs(results[1].job_time.as_secs_f64()),
            fmt_secs(igfs.job_time.as_secs_f64()),
            if lam.ok() {
                let r = reduction(lam, igfs);
                best = best.max(r);
                fmt_pct(r)
            } else {
                "—".into()
            },
        ]);
        assert!(results[1].ok() && igfs.ok());
        if lam.ok() {
            assert!(lam.job_time > igfs.job_time,
                    "IGFS must beat Lambda at {gb} GB");
        }
    }
    t.print();
    println!("max reduction vs lambda: {}", fmt_pct(best));
    assert!(best > 0.5, "grep reduction should stay substantial: {best}");
    println!("fig5 OK");
}
