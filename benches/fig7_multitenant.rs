//! Figure 7 (repo extension): multi-tenant consolidation — one job on
//! a private cluster vs a 4-way mixed co-run over ONE shared cluster.
//!
//! Reports, per configuration: virtual job/makespan times, aggregate
//! virtual throughput (bytes of input retired per virtual second),
//! cross-job warm-container reuse, and the real wall-clock cost of the
//! data planes. Emits `BENCH_fig7_multitenant.json` through the same
//! `util::bench::write_report` flow `bench_diff.py` consumes.

use std::path::Path;

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{
    run_job, stage_named_input, JobServer, SystemConfig,
};
use marvel::runtime::RtEngine;
use marvel::util::bench::{write_report, Bench, BenchResult};
use marvel::util::bytes::MIB;
use marvel::workloads::{Corpus, Grep, PageRank, WordCount};

const SEED: u64 = 42;
const INPUT: u64 = 8 * MIB;

fn base_cfg() -> SystemConfig {
    let mut c = SystemConfig::marvel_igfs();
    c.map_workers = 0; // auto
    c.reduce_workers = 0;
    c
}

fn main() {
    let bench = Bench::new(1, 5);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut metrics: Vec<(&str, f64)> = Vec::new();

    let rt0 = RtEngine::load(None).expect("rt");
    let wc = WordCount::new(10_000, 1.07, &rt0);
    let prefix = Corpus::new(10_000, 1.07).prefix_of_rank(5, 2);
    let grep = Grep::new(10_000, 1.07, &prefix, &rt0);
    let pr = PageRank::new();
    let cfg = base_cfg();

    // -- solo baseline: one wordcount on a private cluster
    let mut solo_virtual_s = 0.0;
    let r_solo = bench.run("solo wordcount 8 MiB (private cluster)", || {
        let mut rt = RtEngine::load(None).expect("rt");
        let mut cluster = ClusterSpec::default().deploy(&cfg);
        cluster.stores.hdfs.block_size = 256 * 1024;
        let input = stage_named_input(&mut cluster, &cfg, &wc, INPUT,
                                      SEED, "solo/in")
            .expect("stage");
        let r = run_job(&mut cluster, &cfg, &wc, &input, &mut rt, SEED);
        assert!(r.ok(), "{:?}", r.failed);
        solo_virtual_s = r.job_time.as_secs_f64();
        r.output_bytes
    });
    println!("{}", r_solo.summary());
    let solo_tput = INPUT as f64 / solo_virtual_s / 1e6;
    println!("  solo: {solo_virtual_s:.3} virtual s → \
              {solo_tput:.1} MB/s (virtual)");
    metrics.push(("solo_virtual_s", solo_virtual_s));
    metrics.push(("solo_virtual_mb_per_s", solo_tput));

    // -- 4-way mixed co-run on one shared cluster
    let mut mk_s = 0.0;
    let mut warm_reuse = 0.0;
    let mut cold = 0.0;
    let r_corun = bench.run("4-way co-run 4×8 MiB (shared cluster)", || {
        let mut rt = RtEngine::load(None).expect("rt");
        let mut cluster = ClusterSpec::default().deploy(&cfg);
        cluster.stores.hdfs.block_size = 256 * 1024;
        let in_wc = stage_named_input(&mut cluster, &cfg, &wc, INPUT,
                                      SEED, "t-wc/in").expect("stage");
        let in_wc2 = stage_named_input(&mut cluster, &cfg, &wc, INPUT,
                                       SEED, "t-wc2/in").expect("stage");
        let in_gr = stage_named_input(&mut cluster, &cfg, &grep, INPUT,
                                      SEED, "t-grep/in").expect("stage");
        let in_pr = stage_named_input(&mut cluster, &cfg, &pr, INPUT,
                                      SEED, "t-pr/in").expect("stage");
        let res = JobServer::new()
            .tenant("t-wc", 1)
            .tenant("t-wc2", 1)
            .tenant("t-grep", 1)
            .tenant("t-pr", 1)
            .job("t-wc", &wc, cfg.clone(), &in_wc, SEED)
            .job("t-wc2", &wc, cfg.clone(), &in_wc2, SEED)
            .job("t-grep", &grep, cfg.clone(), &in_gr, SEED)
            .job("t-pr", &pr, cfg.clone(), &in_pr, SEED)
            .run(&mut cluster, &mut rt);
        assert!(res.ok(), "{:?}", res.failed);
        mk_s = res.makespan.as_secs_f64();
        warm_reuse =
            res.jobs.iter().map(|j| j.cross_job_warm).sum::<u64>() as f64;
        cold = res
            .jobs
            .iter()
            .flat_map(|j| &j.stages)
            .map(|s| s.cold_starts)
            .sum::<u64>() as f64;
        res.jobs.len()
    });
    println!("{}", r_corun.summary());
    let agg_tput = 4.0 * INPUT as f64 / mk_s / 1e6;
    let consolidation = agg_tput / solo_tput.max(1e-9);
    println!(
        "  co-run: {mk_s:.3} virtual s makespan → {agg_tput:.1} MB/s \
         aggregate ({consolidation:.2}× solo), cross-job warm reuse \
         {warm_reuse}, cold starts {cold}"
    );
    metrics.push(("corun_virtual_makespan_s", mk_s));
    metrics.push(("corun_aggregate_virtual_mb_per_s", agg_tput));
    metrics.push(("corun_consolidation_x", consolidation));
    metrics.push(("corun_cross_job_warm", warm_reuse));
    metrics.push(("corun_cold_starts", cold));

    results.extend([r_solo, r_corun]);
    let refs: Vec<&BenchResult> = results.iter().collect();
    let out = Path::new("BENCH_fig7_multitenant.json");
    match write_report(out, &refs, &metrics) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
    println!("fig7_multitenant done");
}
