//! Figure 11 (repo extension): open-loop serving — arrival-rate sweep
//! to the saturation knee.
//!
//! An `OpenLoopServer` drives Poisson wordcount arrivals over one
//! shared 4-node cluster. The admission estimator banks
//! `max_inflight = 2` virtual servers at an `est_service = 2 s`
//! charge, so offered load crosses the service capacity of 1 job/s
//! mid-sweep: rates 0.25 and 0.5 run under the knee, 1.0 sits on it,
//! and 2.0/4.0 drive the server into saturation. Reported per cell:
//! offered/admitted/rejected, sojourn p50/p99/p999, queue-wait p99,
//! and virtual makespan.
//!
//! Expected shape: below the knee every arrival admits and p99 sojourn
//! hugs the bare job time; past the knee queue waits stretch the p99
//! tail and — once the backlog overflows `queue_cap` — admission
//! control starts rejecting, capping the tail at the cost of goodput.
//! The top rate must show both a fatter p99 than the bottom rate and
//! nonzero rejections. Emits `BENCH_fig11_openloop.json` via
//! `util::bench::write_report` for `bench_diff.py`.

use std::path::Path;

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{
    ArrivalConfig, ArrivalModel, OpenLoopServer, SystemConfig, TenantClass,
};
use marvel::runtime::RtEngine;
use marvel::sim::SimNs;
use marvel::util::bench::{write_report, Bench, BenchResult};
use marvel::util::bytes::MIB;
use marvel::workloads::WordCount;

const ARRIVAL_SEED: u64 = 42;
const INPUT: u64 = MIB;
const NODES: usize = 4;
const SLOTS: usize = 8;

fn cfg_for(rate: f64) -> SystemConfig {
    let mut c = SystemConfig::marvel_igfs();
    c.map_workers = 2;
    c.reduce_workers = 2;
    c.arrivals = ArrivalConfig {
        model: ArrivalModel::Poisson { rate },
        seed: ARRIVAL_SEED,
        horizon: SimNs::from_secs_f64(120.0),
        max_jobs: 16,
        classes: vec![
            TenantClass::new("an", 3, 3),
            TenantClass::new("batch", 1, 1),
        ],
        max_inflight: 2,
        queue_cap: 4,
        est_service: SimNs::from_secs_f64(2.0),
    };
    c
}

struct Cell {
    offered: u64,
    admitted: u64,
    rejected: u64,
    sojourn_p50_ms: f64,
    sojourn_p99_ms: f64,
    sojourn_p999_ms: f64,
    queue_wait_p99_ms: f64,
    makespan_s: f64,
}

fn run_cell(cfg: &SystemConfig) -> Cell {
    let mut rt = RtEngine::load(None).expect("rt");
    let mut cluster = ClusterSpec {
        nodes: NODES,
        slots_per_node: SLOTS,
        ..Default::default()
    }
    .deploy(cfg);
    cluster.stores.hdfs.block_size = 256 * 1024; // 4 splits from 1 MiB
    let wc = WordCount::new(10_000, 1.07, &rt);
    let res = OpenLoopServer::new(&wc, cfg.clone(), INPUT)
        .serve(&mut cluster, &mut rt);
    assert!(res.ok(), "serve failed: {:?}", res.failed);
    assert!(res.jobs.iter().all(|j| j.ok()), "an admitted job failed");
    let ol = res.open_loop.expect("open-loop report");
    assert_eq!(ol.offered, ol.admitted + ol.rejected);
    Cell {
        offered: ol.offered,
        admitted: ol.admitted,
        rejected: ol.rejected,
        sojourn_p50_ms: ol.sojourn_ms.p50,
        sojourn_p99_ms: ol.sojourn_ms.p99,
        sojourn_p999_ms: ol.sojourn_ms.p999,
        queue_wait_p99_ms: ol.queue_wait_ms.p99,
        makespan_s: res.makespan.as_secs_f64(),
    }
}

fn main() {
    let bench = Bench::new(1, 3);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut metrics: Vec<(String, f64)> = Vec::new();

    let mut bottom: Option<Cell> = None;
    let mut top: Option<Cell> = None;
    for &rate in &[0.25f64, 0.5, 1.0, 2.0, 4.0] {
        let cfg = cfg_for(rate);
        let mut cell = None;
        let r = bench.run(
            &format!("open-loop wordcount, rate={rate} jobs/s"),
            || {
                let c = run_cell(&cfg);
                let adm = c.admitted;
                cell = Some(c);
                adm
            },
        );
        println!("{}", r.summary());
        let cell = cell.expect("bench ran");
        println!(
            "  rate={rate}: {}/{} admitted ({} rejected), sojourn \
             p50={:.0} ms p99={:.0} ms, queue p99={:.0} ms",
            cell.admitted, cell.offered, cell.rejected,
            cell.sojourn_p50_ms, cell.sojourn_p99_ms,
            cell.queue_wait_p99_ms,
        );

        let tag = format!("rate{:03}", (rate * 100.0) as u32);
        metrics.push((format!("{tag}_offered"), cell.offered as f64));
        metrics.push((format!("{tag}_admitted"), cell.admitted as f64));
        metrics.push((format!("{tag}_rejected"), cell.rejected as f64));
        metrics.push((format!("{tag}_sojourn_p50_ms"), cell.sojourn_p50_ms));
        metrics.push((format!("{tag}_sojourn_p99_ms"), cell.sojourn_p99_ms));
        metrics
            .push((format!("{tag}_sojourn_p999_ms"), cell.sojourn_p999_ms));
        metrics.push((
            format!("{tag}_queue_wait_p99_ms"),
            cell.queue_wait_p99_ms,
        ));
        metrics.push((format!("{tag}_virtual_makespan_s"), cell.makespan_s));
        results.push(r);
        if bottom.is_none() {
            bottom = Some(cell);
        } else {
            top = Some(cell);
        }
    }

    // The fig11 contract: past the knee (service capacity =
    // max_inflight / est_service = 1 job/s) the tail fattens and
    // admission control engages.
    let bottom = bottom.expect("sweep ran");
    let top = top.expect("sweep ran");
    assert!(
        top.sojourn_p99_ms > bottom.sojourn_p99_ms,
        "p99 sojourn must rise past the knee: {:.0} ms at the bottom \
         rate vs {:.0} ms at the top",
        bottom.sojourn_p99_ms,
        top.sojourn_p99_ms
    );
    assert!(
        top.rejected > 0,
        "the top rate must overflow queue_cap and reject"
    );
    assert!(
        top.rejected > bottom.rejected,
        "rejections must engage with offered load"
    );

    let refs: Vec<&BenchResult> = results.iter().collect();
    let met: Vec<(&str, f64)> =
        metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let out = Path::new("BENCH_fig11_openloop.json");
    match write_report(out, &refs, &met) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
    println!("fig11_openloop done");
}
