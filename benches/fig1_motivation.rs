//! Regenerates **Figure 1** (motivation): WordCount job completion time
//! with the Corral library over different storage layers — S3 only,
//! SSD(+S3), PMEM(+S3), PMEM only — at inputs up to 7 GB.
//! Expected shape: PMEM < SSD < S3; "+S3" variants pay the WAN on
//! input/output but keep local intermediate.

use marvel::config::system_by_name;
use marvel::coordinator::{ClusterSpec, Marvel};
use marvel::util::table::{fmt_secs, Table};
use marvel::workloads::WordCount;

const GB: u64 = 1_000_000_000;

fn main() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).expect("marvel");
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let systems = ["lambda-s3", "onprem-ssd+s3", "onprem-ssd",
                   "onprem-pmem+s3", "onprem-pmem"];
    let sizes = [1u64, 3, 5, 7];

    let mut headers = vec!["input (GB)".to_string()];
    headers.extend(systems.iter().map(|s| s.to_string()));
    let refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Figure 1 — WordCount time (s) by storage layer (Corral pipeline)",
        &refs,
    );
    let mut at7 = Vec::new();
    for size in sizes {
        let mut row = vec![size.to_string()];
        for name in systems {
            let cfg = system_by_name(name).unwrap();
            let r = m.run(&cfg, &wc, size * GB);
            let cell = match r.failed {
                Some(_) => "FAIL".to_string(),
                None => fmt_secs(r.job_time.as_secs_f64()),
            };
            if size == 7 {
                at7.push(r.job_time.as_secs_f64());
            }
            row.push(cell);
        }
        t.row(&row);
    }
    t.print();

    // Paper's ordering at 7 GB: PMEM best, SSD close behind, S3 worst.
    let (s3, ssd_s3, ssd, pmem_s3, pmem) =
        (at7[0], at7[1], at7[2], at7[3], at7[4]);
    assert!(pmem < ssd, "pmem {pmem} !< ssd {ssd}");
    assert!(ssd < s3, "ssd {ssd} !< s3 {s3}");
    assert!(pmem_s3 < ssd_s3, "pmem+s3 {pmem_s3} !< ssd+s3 {ssd_s3}");
    assert!(pmem < pmem_s3, "pure pmem must beat pmem+s3");
    println!("fig1 OK: PMEM < SSD < S3 ordering holds at 7 GB");
}
