//! Regenerates **Figure 6**: I/O throughput of the intermediate store
//! (HDFS-on-PMEM vs IGFS) while running WordCount, as a function of
//! input size. Paper shape: IGFS throughput grows with input size and
//! peaks ≈12 Gbps at 10 GB; HDFS stays below IGFS throughout.

use marvel::coordinator::{ClusterSpec, Marvel};
use marvel::mapreduce::SystemConfig;
use marvel::metrics::tags;
use marvel::util::table::Table;
use marvel::workloads::WordCount;

const GB: u64 = 1_000_000_000;

fn main() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).expect("marvel");
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    // Paper-faithful presets: raw shuffle volume (Table 1 expansion).
    let hdfs = SystemConfig::marvel_hdfs_paper();
    let igfs = SystemConfig::marvel_igfs_paper();

    let shuffle_tags =
        [tags::INTERMEDIATE_WRITE, tags::INTERMEDIATE_READ];
    let sizes_gb = [0.5f64, 1.0, 2.0, 5.0, 8.0, 10.0];
    let mut t = Table::new(
        "Figure 6 — shuffle I/O throughput (Gbps), WordCount",
        &["input (GB)", "HDFS (PMEM)", "IGFS", "IGFS busy-span Gbps"],
    );
    let mut igfs_series = Vec::new();
    for gb in sizes_gb {
        let bytes = (gb * GB as f64) as u64;
        let rh = m.run(&hdfs, &wc, bytes);
        let ri = m.run(&igfs, &wc, bytes);
        assert!(rh.ok() && ri.ok());
        let h_gbps = rh.io.gbps_over_makespan(&shuffle_tags);
        let i_gbps = ri.io.gbps_over_makespan(&shuffle_tags);
        let i_busy = ri.io.gbps_for(tags::INTERMEDIATE_WRITE);
        igfs_series.push(i_gbps);
        t.row(&[
            format!("{gb}"),
            format!("{h_gbps:.2}"),
            format!("{i_gbps:.2}"),
            format!("{i_busy:.2}"),
        ]);
        assert!(i_gbps >= h_gbps,
                "IGFS throughput must dominate HDFS at {gb} GB");
    }
    t.print();
    // Shape: throughput grows with input (startup amortized out).
    assert!(igfs_series.last().unwrap() > igfs_series.first().unwrap(),
            "IGFS throughput should rise with input size");
    println!("fig6 OK: IGFS > HDFS and rising-with-size shape holds");
}
