//! Figure 13 (repo extension): skew-aware repartitioning on the
//! star-schema join suite — partitioner × Zipf-exponent sweep.
//!
//! The join → group-by pipeline runs over 256 MiB of synthetic
//! fact+dimension tables at s ∈ {0, 1.2, 1.5} under `Hash` and
//! `SkewAware`. The contract this figure pins:
//!
//! * **s = 0** (uniform keys): the skew planner detects nothing, the
//!   plan degenerates to hash routing, and the virtual makespan is
//!   EXACTLY the hash cell's — skew-awareness is free when there is no
//!   skew.
//! * **s ≥ 1.2** (skewed): the planner flags hot keys at plan time
//!   (`hot_keys_split > 0`), splits them across reducers, the group-by
//!   gains a merge stage, and the total makespan — merge included —
//!   beats `Hash` strictly, with a visibly flatter per-partition byte
//!   census (`partition_skew`).
//!
//! Emits `BENCH_fig13_skewjoin.json` via `util::bench::write_report`
//! for `bench_diff.py`.

use std::path::Path;

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{
    stage_named_input, Cluster, JobPipeline, Partitioner, SystemConfig,
};
use marvel::runtime::RtEngine;
use marvel::util::bench::{write_report, Bench, BenchResult};
use marvel::util::bytes::MIB;
use marvel::workloads::{GroupBy, RepartitionJoin, StarSchema};

const SEED: u64 = 13;
/// Past the materialize cap: the sweep runs on synthetic payloads and
/// the analytic accounting, like the paper-scale figures.
const INPUT: u64 = 256 * MIB;
const DIM_KEYS: u64 = 1024;
const NODES: usize = 4;
const SLOTS: usize = 8;

fn skew() -> Partitioner {
    Partitioner::SkewAware { hot_threshold: 1.3, split_ways: 4 }
}

fn cfg_for(p: &Partitioner) -> SystemConfig {
    let mut c = SystemConfig::marvel_igfs();
    c.partition = p.clone();
    c.map_workers = 2;
    c.reduce_workers = 2;
    c
}

fn deploy(cfg: &SystemConfig) -> Cluster {
    let mut cluster = ClusterSpec {
        nodes: NODES,
        slots_per_node: SLOTS,
        ..Default::default()
    }
    .deploy(cfg);
    cluster
}

struct Cell {
    makespan_s: f64,
    join_skew: f64,
    hot_keys_split: u64,
    merged: bool,
    final_bytes: u64,
}

/// One sweep cell: join → group-by (the pipeline appends the merge
/// stage itself whenever the plan split hot keys).
fn run_cell(zipf_s: f64, p: &Partitioner) -> Cell {
    let cfg = cfg_for(p);
    let mut rt = RtEngine::load(None).expect("rt");
    let mut cluster = deploy(&cfg);
    let join = RepartitionJoin::new(StarSchema::new(DIM_KEYS, zipf_s));
    let gb = GroupBy::new(StarSchema::new(DIM_KEYS, zipf_s));
    let input = stage_named_input(
        &mut cluster, &cfg, &join, INPUT, SEED, "sj/in",
    )
    .expect("stage");
    let res = JobPipeline::new("fig13")
        .stage(&join, cfg.clone())
        .stage(&gb, cfg.clone())
        .run(&mut cluster, &mut rt, SEED, &input);
    assert!(res.ok(), "s={zipf_s} {}: {:?}", p.name(), res.failed);
    let fin = res.final_output().expect("final stage");
    Cell {
        makespan_s: res.job_time.as_secs_f64(),
        join_skew: res.stages[0].partition_skew,
        hot_keys_split: res
            .stages
            .iter()
            .map(|s| s.hot_keys_split)
            .sum(),
        merged: res.merges.iter().any(|m| m.is_some()),
        final_bytes: fin.output_bytes,
    }
}

fn main() {
    let bench = Bench::new(1, 3);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut metrics: Vec<(String, f64)> = Vec::new();

    for zipf_s in [0.0f64, 1.2, 1.5] {
        let mut cells: Vec<Cell> = Vec::new();
        for p in [Partitioner::Hash, skew()] {
            let mut cell = None;
            let label =
                format!("starjoin 256 MiB, s={zipf_s}, {}", p.name());
            let r = bench.run(&label, || {
                let c = run_cell(zipf_s, &p);
                let out = c.final_bytes;
                cell = Some(c);
                out
            });
            println!("{}", r.summary());
            let cell = cell.expect("bench ran");
            println!(
                "  s={zipf_s} {}: {:.3} virtual s, join skew {:.2} \
                 p99/median, {} hot keys split{}",
                p.name(),
                cell.makespan_s,
                cell.join_skew,
                cell.hot_keys_split,
                if cell.merged { ", merge stage ran" } else { "" },
            );
            let tag = format!(
                "s{}_{}",
                (zipf_s * 10.0).round() as u64,
                p.name().replace('-', "_")
            );
            metrics.push((format!("{tag}_virtual_makespan_s"),
                          cell.makespan_s));
            metrics.push((format!("{tag}_join_partition_skew"),
                          cell.join_skew));
            metrics.push((format!("{tag}_hot_keys_split"),
                          cell.hot_keys_split as f64));
            results.push(r);
            cells.push(cell);
        }
        let (hash, sk) = (&cells[0], &cells[1]);
        assert_eq!(
            hash.final_bytes, sk.final_bytes,
            "s={zipf_s}: partitioners diverged on final bytes"
        );
        assert_eq!(hash.hot_keys_split, 0, "hash never splits");
        assert!(!hash.merged, "hash never owes a merge");
        if zipf_s == 0.0 {
            // Uniform keys: skew-awareness must be exactly free.
            assert_eq!(sk.hot_keys_split, 0,
                       "nothing is hot under a uniform profile");
            assert!(!sk.merged);
            assert_eq!(
                sk.makespan_s, hash.makespan_s,
                "s=0: skew-aware must equal hash bit-for-bit"
            );
        } else {
            // The fig13 contract: detect, split, merge — and still win.
            assert!(sk.hot_keys_split > 0,
                    "s={zipf_s}: planner flagged no hot keys");
            assert!(sk.merged,
                    "s={zipf_s}: group-by split without a merge stage");
            assert!(
                sk.makespan_s < hash.makespan_s,
                "s={zipf_s}: skew-aware {:.3}s !< hash {:.3}s",
                sk.makespan_s, hash.makespan_s
            );
            assert!(
                sk.join_skew < hash.join_skew,
                "s={zipf_s}: split plan must flatten the byte census \
                 ({:.2} !< {:.2})",
                sk.join_skew, hash.join_skew
            );
            metrics.push((
                format!("s{}_speedup_vs_hash",
                        (zipf_s * 10.0).round() as u64),
                hash.makespan_s / sk.makespan_s.max(1e-9),
            ));
        }
    }

    let refs: Vec<&BenchResult> = results.iter().collect();
    let met: Vec<(&str, f64)> =
        metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let out = Path::new("BENCH_fig13_skewjoin.json");
    match write_report(out, &refs, &met) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
    println!("fig13_skewjoin done");
}
