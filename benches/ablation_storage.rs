//! Ablations over the storage substrate: HDFS backing device
//! (PMEM / SSD / HDD), replication factor, and container pre-warming —
//! the deployment knobs ARCHITECTURE.md (Layer 1) calls out.

use marvel::coordinator::{ClusterSpec, Marvel};
use marvel::mapreduce::SystemConfig;
use marvel::net::DeviceRole;
use marvel::util::table::{fmt_secs, Table};
use marvel::workloads::WordCount;

const GB: u64 = 1_000_000_000;

fn main() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).expect("marvel");
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let input = 5 * GB;

    // -- backing device sweep (marvel-hdfs shape, combiner off to
    //    stress the storage path)
    let mut t = Table::new(
        "Ablation — HDFS backing device (WordCount 5 GB, raw shuffle)",
        &["device", "job time", "map", "reduce"],
    );
    let mut times = Vec::new();
    for role in [DeviceRole::Pmem, DeviceRole::Ssd, DeviceRole::Hdd] {
        let mut cfg = SystemConfig::onprem(role, false);
        cfg.name = format!("{role:?}").to_lowercase();
        let r = m.run(&cfg, &wc, input);
        assert!(r.ok(), "{:?}: {:?}", role, r.failed);
        times.push(r.job_time.as_secs_f64());
        t.row(&[
            cfg.name.clone(),
            fmt_secs(r.job_time.as_secs_f64()),
            fmt_secs(r.map.duration.as_secs_f64()),
            fmt_secs(r.reduce.duration.as_secs_f64()),
        ]);
    }
    t.print();
    assert!(times[0] < times[1] && times[1] < times[2],
            "device ordering must be pmem < ssd < hdd: {times:?}");

    // -- replication factor on a 4-node cluster
    let spec4 = ClusterSpec::with_nodes(4);
    let mut m4 = Marvel::new(spec4, 42).expect("marvel");
    let wc4 = WordCount::new(10_000, 1.07, &m4.rt);
    let mut t = Table::new(
        "Ablation — HDFS replication (4 nodes, WordCount 5 GB)",
        &["replication", "job time", "locality"],
    );
    let mut rep_times = Vec::new();
    for rep in [1usize, 2, 3] {
        let mut cfg = SystemConfig::marvel_hdfs();
        cfg.replication = rep;
        cfg.name = format!("marvel-hdfs/r{rep}");
        let r = m4.run(&cfg, &wc4, input);
        assert!(r.ok());
        rep_times.push(r.job_time.as_secs_f64());
        t.row(&[
            rep.to_string(),
            fmt_secs(r.job_time.as_secs_f64()),
            format!("{:.0} %", r.locality_ratio * 100.0),
        ]);
    }
    t.print();
    // With single-writer ingest, r=1 concentrates every block on the
    // writer node (a real HDFS hot-spot); r>=2 spreads replicas and
    // recovers locality+parallelism. Expect r2/r3 to beat r1 and to be
    // within noise of each other (pipeline cost hidden by the NIC).
    assert!(rep_times[1] <= rep_times[0],
            "replication should relieve the ingest hot-spot: {rep_times:?}");
    assert!(rep_times[2] >= rep_times[1] * 0.95,
            "r3 cannot be much faster than r2: {rep_times:?}");

    // -- prewarm vs cold pools
    let mut t = Table::new(
        "Ablation — container pre-warming (WordCount 0.5 GB)",
        &["prewarm", "job time", "cold starts"],
    );
    let mut pw_times = Vec::new();
    for prewarm in [true, false] {
        let mut cfg = SystemConfig::marvel_igfs();
        cfg.prewarm = prewarm;
        cfg.name = format!("marvel-igfs/prewarm={prewarm}");
        let r = m.run(&cfg, &wc, GB / 2);
        assert!(r.ok());
        pw_times.push(r.job_time.as_secs_f64());
        t.row(&[
            prewarm.to_string(),
            fmt_secs(r.job_time.as_secs_f64()),
            r.cold_starts.to_string(),
        ]);
    }
    t.print();
    assert!(pw_times[0] <= pw_times[1],
            "prewarm must not slow the job: {pw_times:?}");
    println!("ablation_storage OK");
}
