//! Figure 12 (repo extension): pluggable task placement — locality &
//! cache-affinity scheduling, strategy × workload.
//!
//! Two workloads sweep the `PlacementStrategy` axis on a 4-node
//! cluster:
//!
//! * **wordcount** (single stage, HDFS input): every strategy runs the
//!   same job; reported per cell are virtual makespan, byte-weighted
//!   `locality_ratio`, `affinity_hits`, and remote-read ("WAN") bytes.
//!   `HdfsLocal` reads every input byte node-local; `FairOrder`
//!   reproduces the default-config timings bit-for-bit (placement OFF
//!   is placement FairOrder).
//! * **pipeline** (wordcount seeding PageRank over the IGFS handoff):
//!   `CacheAffinity` routes stage-2 maps to the DRAM/PMEM owners of
//!   stage 1's outputs and must CUT both remote handoff bytes and
//!   total makespan against a `Random` baseline (seed searched so the
//!   baseline actually pays remote reads — a lucky all-local draw
//!   would make the contrast vacuous).
//!
//! Placement never moves a byte: every cell's output is asserted
//! byte-identical. Emits `BENCH_fig12_placement.json` via
//! `util::bench::write_report` for `bench_diff.py`.

use std::path::Path;

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{
    run_job, stage_named_input, Cluster, JobPipeline, PlacementStrategy,
    StoreKind, SystemConfig,
};
use marvel::runtime::RtEngine;
use marvel::util::bench::{write_report, Bench, BenchResult};
use marvel::util::bytes::MIB;
use marvel::workloads::{PageRank, WordCount};

const SEED: u64 = 42;
const INPUT: u64 = 8 * MIB;
const NODES: usize = 4;
const SLOTS: usize = 8;

fn cfg_for(strategy: PlacementStrategy) -> SystemConfig {
    let mut c = SystemConfig::marvel_igfs();
    c.placement = strategy;
    c.map_workers = 2;
    c.reduce_workers = 2;
    c
}

fn deploy(cfg: &SystemConfig) -> Cluster {
    let mut cluster = ClusterSpec {
        nodes: NODES,
        slots_per_node: SLOTS,
        ..Default::default()
    }
    .deploy(cfg);
    cluster.stores.hdfs.block_size = 256 * 1024; // 32 splits from 8 MiB
    cluster
}

struct Cell {
    makespan_s: f64,
    locality: f64,
    affinity_hits: u64,
    remote_bytes: f64,
    output_bytes: u64,
}

/// Single-stage wordcount under `cfg`.
fn run_wc(cfg: &SystemConfig) -> Cell {
    let mut rt = RtEngine::load(None).expect("rt");
    let mut cluster = deploy(cfg);
    let wc = WordCount::new(10_000, 1.07, &rt);
    let input =
        stage_named_input(&mut cluster, cfg, &wc, INPUT, SEED, "wc/in")
            .expect("stage");
    let r = run_job(&mut cluster, cfg, &wc, &input, &mut rt, SEED);
    assert!(r.ok(), "{:?}: {:?}", cfg.placement, r.failed);
    Cell {
        makespan_s: r.job_time.as_secs_f64(),
        locality: r.locality_ratio,
        affinity_hits: r.affinity_hits,
        remote_bytes: (1.0 - r.locality_ratio) * r.input_bytes as f64,
        output_bytes: r.output_bytes,
    }
}

/// Two-stage wordcount → PageRank pipeline with the handoff riding
/// IGFS; folds both stages into one cell (stage-2 locality is the
/// handoff-affinity signal).
fn run_pipe(cfg: &SystemConfig) -> Cell {
    let mut rt = RtEngine::load(None).expect("rt");
    let mut stage_cfg = cfg.clone();
    stage_cfg.output_store = StoreKind::Igfs;
    let mut cluster = deploy(cfg);
    let wc = WordCount::new(10_000, 1.07, &rt);
    let pr = PageRank::new();
    let input = stage_named_input(
        &mut cluster, cfg, &wc, INPUT, SEED, "pipe/in",
    )
    .expect("stage");
    let res = JobPipeline::new("pipe")
        .stage(&wc, stage_cfg.clone())
        .stage(&pr, stage_cfg.clone())
        .run(&mut cluster, &mut rt, SEED, &input);
    assert!(res.ok(), "{:?}: {:?}", cfg.placement, res.failed);
    let s2 = &res.stages[1];
    Cell {
        makespan_s: res.job_time.as_secs_f64(),
        locality: s2.locality_ratio,
        affinity_hits: res.stages.iter().map(|s| s.affinity_hits).sum(),
        remote_bytes: res
            .stages
            .iter()
            .map(|s| (1.0 - s.locality_ratio) * s.input_bytes as f64)
            .sum(),
        output_bytes: res.stages.last().unwrap().output_bytes,
    }
}

const STRATEGIES: [PlacementStrategy; 6] = [
    PlacementStrategy::FairOrder,
    PlacementStrategy::Random { seed: 7 },
    PlacementStrategy::RoundRobin,
    PlacementStrategy::HdfsLocal,
    PlacementStrategy::CacheAffinity,
    PlacementStrategy::StragglerAware,
];

fn main() {
    let bench = Bench::new(1, 3);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut metrics: Vec<(String, f64)> = Vec::new();

    // ── Workload 1: single-stage wordcount, all six strategies. ──
    let mut baseline_output = None;
    let mut fair_cell: Option<Cell> = None;
    for s in STRATEGIES {
        let cfg = cfg_for(s);
        let mut cell = None;
        let r = bench.run(&format!("wordcount 8 MiB, {}", s.name()), || {
            let c = run_wc(&cfg);
            let out = c.output_bytes;
            cell = Some(c);
            out
        });
        println!("{}", r.summary());
        let cell = cell.expect("bench ran");
        println!(
            "  {}: {:.3} virtual s, locality {:.2}, {} affinity hits, \
             {:.0} remote bytes",
            s.name(), cell.makespan_s, cell.locality,
            cell.affinity_hits, cell.remote_bytes,
        );

        // Placement never moves a byte.
        match baseline_output {
            None => baseline_output = Some(cell.output_bytes),
            Some(b) => assert_eq!(
                cell.output_bytes, b,
                "{} moved bytes", s.name()
            ),
        }
        if s == PlacementStrategy::HdfsLocal {
            assert_eq!(
                cell.locality, 1.0,
                "HdfsLocal must read every input byte node-local"
            );
        }
        let tag = format!("wc_{}", s.name().replace('-', "_"));
        metrics.push((format!("{tag}_virtual_makespan_s"),
                      cell.makespan_s));
        metrics.push((format!("{tag}_locality_ratio"), cell.locality));
        metrics.push((format!("{tag}_affinity_hits"),
                      cell.affinity_hits as f64));
        metrics.push((format!("{tag}_remote_bytes"), cell.remote_bytes));
        results.push(r);
        if s == PlacementStrategy::FairOrder {
            fair_cell = Some(cell);
        }
    }

    // FairOrder IS the pre-placement scheduler: a config that never
    // heard of `[placement]` must land on identical virtual timings.
    let default_cell = run_wc(&cfg_for(PlacementStrategy::default()));
    let fair = fair_cell.expect("fair cell ran");
    assert_eq!(
        fair.makespan_s, default_cell.makespan_s,
        "FairOrder must reproduce default-config timings bit-for-bit"
    );
    assert_eq!(fair.locality, default_cell.locality);

    // ── Workload 2: pipeline, CacheAffinity vs a paying Random. ──
    // Search the Random seed space for a baseline that actually reads
    // stage-2 handoff bytes remotely; an all-local lucky draw would
    // make the "cuts remote bytes" contrast vacuous.
    let (rseed, rand_cell) = (0..16u64)
        .map(|s| {
            (s, run_pipe(&cfg_for(PlacementStrategy::Random { seed: s })))
        })
        .find(|(_, c)| c.remote_bytes > 0.0)
        .expect("a remote-paying random seed exists in 16 draws");
    let r = bench.run("pipeline 8 MiB, random (paying)", || {
        run_pipe(&cfg_for(PlacementStrategy::Random { seed: rseed }))
            .output_bytes
    });
    println!("{}", r.summary());
    results.push(r);

    let mut aff_cell = None;
    let r = bench.run("pipeline 8 MiB, cache-affinity", || {
        let c = run_pipe(&cfg_for(PlacementStrategy::CacheAffinity));
        let out = c.output_bytes;
        aff_cell = Some(c);
        out
    });
    println!("{}", r.summary());
    results.push(r);
    let aff = aff_cell.expect("bench ran");

    println!(
        "  pipeline: random(seed={rseed}) {:.3}s / {:.0} remote bytes \
         vs cache-affinity {:.3}s / {:.0} remote bytes",
        rand_cell.makespan_s, rand_cell.remote_bytes,
        aff.makespan_s, aff.remote_bytes,
    );
    assert_eq!(
        aff.output_bytes, rand_cell.output_bytes,
        "strategies diverged on pipeline bytes"
    );
    // The fig12 contract: affinity routing cuts remote handoff bytes
    // AND total makespan against the random baseline.
    assert_eq!(
        aff.locality, 1.0,
        "CacheAffinity must read every stage-2 handoff byte on its owner"
    );
    assert!(
        aff.remote_bytes < rand_cell.remote_bytes,
        "CacheAffinity must cut remote bytes: {} vs {}",
        aff.remote_bytes, rand_cell.remote_bytes
    );
    assert!(
        aff.makespan_s < rand_cell.makespan_s,
        "CacheAffinity must cut makespan: {} vs {}",
        aff.makespan_s, rand_cell.makespan_s
    );

    metrics.push(("pipe_random_virtual_makespan_s".into(),
                  rand_cell.makespan_s));
    metrics.push(("pipe_random_remote_bytes".into(),
                  rand_cell.remote_bytes));
    metrics.push(("pipe_random_stage2_locality".into(),
                  rand_cell.locality));
    metrics.push(("pipe_cache_affinity_virtual_makespan_s".into(),
                  aff.makespan_s));
    metrics.push(("pipe_cache_affinity_remote_bytes".into(),
                  aff.remote_bytes));
    metrics.push(("pipe_cache_affinity_stage2_locality".into(),
                  aff.locality));
    metrics.push(("pipe_speedup_vs_random".into(),
                  rand_cell.makespan_s / aff.makespan_s.max(1e-9)));

    let refs: Vec<&BenchResult> = results.iter().collect();
    let met: Vec<(&str, f64)> =
        metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let out = Path::new("BENCH_fig12_placement.json");
    match write_report(out, &refs, &met) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
    println!("fig12_placement done");
}
