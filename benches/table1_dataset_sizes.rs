//! Regenerates **Table 1**: dataset sizes at different MapReduce phases
//! (input / intermediate / output) for Scan, Aggregation, Join and
//! WordCount at the paper's own input sizes. The paper measured these
//! on the stateless (pre-combine, JSON-framed) pipeline — we use the
//! same configuration.

use marvel::coordinator::{ClusterSpec, Marvel};
use marvel::mapreduce::{SystemConfig, Workload};
use marvel::net::DeviceRole;
use marvel::util::table::Table;
use marvel::workloads::{AggregationQuery, JoinQuery, ScanQuery, WordCount};

const GB: u64 = 1_000_000_000;

fn gb(x: f64) -> u64 {
    (x * GB as f64) as u64
}

fn main() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).expect("marvel");
    // Table 1 methodology: stateless pipeline, JSON records, no combine.
    let cfg = SystemConfig::onprem(DeviceRole::Pmem, false);

    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let agg = AggregationQuery::new(&m.rt);
    let scan = ScanQuery::new();
    let join = JoinQuery::new();
    // (workload, label, paper rows: (input, intermediate, output) GB)
    let spec: Vec<(&dyn Workload, &str, Vec<(f64, f64, f64)>)> = vec![
        (&scan, "Scan Query",
         vec![(0.54, 0.76, 0.1), (1.2, 1.3, 0.16), (5.7, 6.7, 0.81)]),
        (&agg, "Aggregation Query",
         vec![(10.5, 17.4, 0.01), (26.3, 32.0, 0.03), (58.0, 74.0, 0.03)]),
        (&join, "Join Query",
         vec![(12.5, 49.6, 9.7), (27.5, 103.0, 22.6), (63.7, 242.0, 51.0)]),
        (&wc, "Word Count",
         vec![(1.0, 5.5, 0.01), (5.0, 28.0, 0.03), (10.0, 56.0, 0.1),
              (50.0, 291.0, 0.4)]),
    ];

    let mut t = Table::new(
        "Table 1 — Dataset sizes at different MapReduce phases (GB)",
        &["workload", "input", "intermediate", "paper", "output", "paper"],
    );
    for (wl, label, rows) in &spec {
        for (in_gb, p_int, p_out) in rows {
            let r = m.run(&cfg, *wl, gb(*in_gb));
            assert!(r.ok(), "{label} {in_gb} GB: {:?}", r.failed);
            t.row(&[
                label.to_string(),
                format!("{in_gb}"),
                format!("{:.2}", r.intermediate_bytes as f64 / GB as f64),
                format!("{p_int}"),
                format!("{:.3}", r.output_bytes as f64 / GB as f64),
                format!("{p_out}"),
            ]);
            // Shape assertions: intermediate-to-input ratio in the same
            // regime as the paper's (who-expands-how-much).
            let ratio = r.intermediate_bytes as f64 / r.input_bytes as f64;
            let paper_ratio = p_int / in_gb;
            match *label {
                "Word Count" => assert!(ratio > 3.0 && ratio < 8.0,
                                        "wc ratio {ratio}"),
                "Join Query" => assert!(ratio > 2.0 && ratio < 6.0,
                                        "join ratio {ratio}"),
                _ => assert!(ratio > 0.5 && ratio < 2.5,
                             "{label} ratio {ratio} (paper {paper_ratio})"),
            }
        }
    }
    t.print();
    println!("table1 OK: expansion regimes match the paper's");
}
