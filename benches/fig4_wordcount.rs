//! Regenerates **Figure 4**: WordCount execution time vs input size for
//! Lambda+S3 (Corral), Marvel-HDFS (PMEM) and Marvel-IGFS.
//! Expected shape: Lambda fails past its 15 GB quota; Marvel-IGFS ≤
//! Marvel-HDFS ≪ Lambda; the headline reduction at the largest common
//! point ≈ 86.6 %.

use marvel::coordinator::{reduction, ClusterSpec, Marvel};
use marvel::mapreduce::SystemConfig;
use marvel::util::table::{fmt_pct, fmt_secs, Table};
use marvel::workloads::WordCount;

const GB: u64 = 1_000_000_000;

fn main() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).expect("marvel");
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let sizes_gb = [0.5f64, 1.0, 2.0, 5.0, 7.0, 10.0, 15.0, 20.0, 50.0];
    let configs = [
        SystemConfig::corral_lambda(),
        SystemConfig::marvel_hdfs_paper(),
        SystemConfig::marvel_igfs_paper(),
    ];

    let mut t = Table::new(
        "Figure 4 — WordCount execution time (s)",
        &["input (GB)", "lambda-s3", "marvel-hdfs", "marvel-igfs",
          "reduction vs lambda"],
    );
    let mut best_reduction: f64 = 0.0;
    for gb in sizes_gb {
        let results = m.compare(&configs, &wc, (gb * GB as f64) as u64);
        let lam = &results[0];
        let igfs = &results[2];
        let red = if lam.ok() {
            let r = reduction(lam, igfs);
            best_reduction = best_reduction.max(r);
            fmt_pct(r)
        } else {
            "—".into()
        };
        t.row(&[
            format!("{gb}"),
            if lam.ok() { fmt_secs(lam.job_time.as_secs_f64()) }
            else { "FAIL (quota)".into() },
            fmt_secs(results[1].job_time.as_secs_f64()),
            fmt_secs(igfs.job_time.as_secs_f64()),
            red,
        ]);
        // Shape invariants per size.
        assert!(results[1].ok() && igfs.ok(),
                "Marvel must complete at {gb} GB");
        if lam.ok() {
            assert!(lam.job_time > igfs.job_time,
                    "IGFS must beat Lambda at {gb} GB");
        } else {
            assert!(gb > 15.0, "Lambda failed below the quota at {gb} GB");
        }
        assert!(results[1].job_time >= igfs.job_time,
                "IGFS must not lose to HDFS at {gb} GB");
    }
    t.print();
    println!("max reduction vs lambda: {} (paper: up to 86.6 %)",
             fmt_pct(best_reduction));
    assert!(best_reduction > 0.70 && best_reduction <= 0.95,
            "headline reduction out of regime: {best_reduction}");
    println!("fig4 OK: ordering, quota failure, and reduction regime hold");
}
