//! Figure 9 (repo extension): heterogeneous node speeds + speculative
//! execution — tail latency quantified.
//!
//! One wordcount runs on a 4-node cluster with one straggler node
//! (staging node kept fast so task placement spreads), sweeping the
//! straggler slowdown × speculation on/off. Reported per cell: virtual
//! makespan, backups launched, races the backup won, and task
//! attempts. Outputs are byte-count-identical in every cell (asserted
//! — stragglers and speculation are time-plane-only knobs). Expected
//! shape: without speculation the makespan tracks the slowdown almost
//! linearly (the slow node's tasks are the critical path); with
//! speculation most of the slowdown is recovered for bounded duplicate
//! work (one backup per laggard). Emits `BENCH_fig9_stragglers.json`
//! through the same `util::bench::write_report` flow `bench_diff.py`
//! consumes.

use std::path::Path;

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{run_job, stage_named_input, SystemConfig};
use marvel::net::StragglerProfile;
use marvel::runtime::RtEngine;
use marvel::util::bench::{write_report, Bench, BenchResult};
use marvel::util::bytes::MIB;
use marvel::workloads::WordCount;

const SEED: u64 = 42;
const INPUT: u64 = 8 * MIB;
const NODES: usize = 4;
const SLOTS: usize = 8;
const PROB: f64 = 0.4;

/// Straggler seed with node 0 (staging/locality) fast and exactly one
/// slow node among the rest — deterministic scan over the pure
/// `speed_of` function, so the bench shape is stable across runs.
fn mixed_seed(slowdown: f64) -> u64 {
    (0..50_000u64)
        .find(|&s| {
            let p = StragglerProfile { seed: s, prob: PROB, slowdown };
            let sp = p.speeds(NODES);
            sp[0] == 1.0
                && sp[1..].iter().filter(|v| **v < 1.0).count() == 1
        })
        .expect("a mixed straggler draw exists")
}

fn cfg_for(slowdown: f64, speculation: bool) -> SystemConfig {
    let mut c = SystemConfig::marvel_igfs();
    c.map_workers = 2;
    c.reduce_workers = 2;
    if slowdown > 1.0 {
        c.stragglers = StragglerProfile {
            seed: mixed_seed(slowdown),
            prob: PROB,
            slowdown,
        };
    }
    c.speculation.enabled = speculation;
    c
}

struct Cell {
    makespan_s: f64,
    backups: u64,
    wins: u64,
    attempts: u64,
    output_bytes: u64,
}

fn run_cell(cfg: &SystemConfig) -> Cell {
    let mut rt = RtEngine::load(None).expect("rt");
    let mut cluster = ClusterSpec {
        nodes: NODES,
        slots_per_node: SLOTS,
        ..Default::default()
    }
    .deploy(cfg);
    cluster.stores.hdfs.block_size = 256 * 1024; // 32 splits from 8 MiB
    let wc = WordCount::new(10_000, 1.07, &rt);
    let input =
        stage_named_input(&mut cluster, cfg, &wc, INPUT, SEED, "wc/in")
            .expect("stage");
    let r = run_job(&mut cluster, cfg, &wc, &input, &mut rt, SEED);
    assert!(r.ok(), "{:?}", r.failed);
    Cell {
        makespan_s: r.job_time.as_secs_f64(),
        backups: r.spec_backups,
        wins: r.spec_backup_wins,
        attempts: r.task_attempts,
        output_bytes: r.output_bytes,
    }
}

fn main() {
    let bench = Bench::new(1, 3);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut metrics: Vec<(String, f64)> = Vec::new();

    let mut baseline_output = None;
    for &slowdown in &[1.0f64, 2.0, 4.0, 8.0] {
        let mut cells = Vec::new();
        for spec_on in [false, true] {
            let mode = if spec_on { "spec-on" } else { "spec-off" };
            let cfg = cfg_for(slowdown, spec_on);
            let mut cell = None;
            let r = bench.run(
                &format!("wordcount 8 MiB, slowdown={slowdown}, {mode}"),
                || {
                    let c = run_cell(&cfg);
                    let out = c.output_bytes;
                    cell = Some(c);
                    out
                },
            );
            println!("{}", r.summary());
            let cell = cell.expect("bench ran");
            // The straggler determinism contract, asserted per cell:
            // node speeds and speculation never move output bytes.
            match baseline_output {
                None => baseline_output = Some(cell.output_bytes),
                Some(b) => assert_eq!(
                    cell.output_bytes, b,
                    "outputs must be byte-count-identical at \
                     slowdown={slowdown}"
                ),
            }
            println!(
                "  {mode} x{slowdown}: {:.3} virtual s, {} backups \
                 ({} won), {} attempts",
                cell.makespan_s, cell.backups, cell.wins, cell.attempts,
            );
            let tag = format!("x{:02}_{mode}", slowdown as u32);
            metrics.push((format!("{tag}_virtual_makespan_s"),
                          cell.makespan_s));
            metrics.push((format!("{tag}_spec_backups"),
                          cell.backups as f64));
            metrics.push((format!("{tag}_spec_backup_wins"),
                          cell.wins as f64));
            metrics.push((format!("{tag}_task_attempts"),
                          cell.attempts as f64));
            cells.push(cell);
            results.push(r);
        }
        // The fig9 shape. Uniform cluster: nothing lags the median, so
        // speculation must be a no-op. Pronounced stragglers: backups
        // must launch and cut the makespan.
        if slowdown <= 1.0 {
            assert_eq!(cells[1].backups, 0,
                       "uniform cluster must not speculate");
            assert!(
                (cells[1].makespan_s - cells[0].makespan_s).abs()
                    < 1e-9 + 0.01 * cells[0].makespan_s,
                "speculation-on must be a no-op on a uniform cluster"
            );
        } else if slowdown >= 4.0 {
            assert!(cells[1].backups > 0,
                    "stragglers at x{slowdown} must trigger backups");
            assert!(
                cells[1].makespan_s < cells[0].makespan_s,
                "speculation must reduce makespan at x{slowdown}: \
                 on={} off={}",
                cells[1].makespan_s,
                cells[0].makespan_s
            );
        }
    }

    let refs: Vec<&BenchResult> = results.iter().collect();
    let met: Vec<(&str, f64)> =
        metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let out = Path::new("BENCH_fig9_stragglers.json");
    match write_report(out, &refs, &met) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
    println!("fig9_stragglers done");
}
