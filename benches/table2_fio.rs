//! Regenerates **Table 2**: IOPS, Bandwidth, Latency for PMEM vs. SSD
//! (FIO-style, 4 KiB blocks, 8 parallel streams) — side by side with
//! the paper's published numbers.

use marvel::storage::fio;
use marvel::storage::{Access, Dir};
use marvel::util::table::Table;

/// Paper Table 2 values: (kiops, GiB/s, latency-as-printed).
fn paper_row(access: Access, dir: Dir, media: &str) -> (f64, f64, &'static str) {
    match (access, dir, media) {
        (Access::Seq, Dir::Read, "pmem") => (10700.0, 41.0, "0.6 us"),
        (Access::Seq, Dir::Read, "ssd") => (108.0, 0.4, "4.7 ms"),
        (Access::Seq, Dir::Write, "pmem") => (3314.0, 13.6, "1.9 us"),
        (Access::Seq, Dir::Write, "ssd") => (118.0, 0.5, "5.0 ms"),
        (Access::Rand, Dir::Read, "pmem") => (1166.0, 4.6, "0.6 us"),
        (Access::Rand, Dir::Read, "ssd") => (82.3, 0.3, "0.8 ms"),
        (Access::Rand, Dir::Write, "pmem") => (335.0, 1.4, "2.3 us"),
        (Access::Rand, Dir::Write, "ssd") => (66.2, 0.3, "1.0 ms"),
        _ => unreachable!(),
    }
}

fn main() {
    let rows = fio::table2(8, 200_000);
    let mut t = Table::new(
        "Table 2 — IOPS, Bandwidth, Latency: PMEM vs SSD (4 KiB, 8 streams)",
        &["benchmark", "media", "IOPS (K)", "paper", "GiB/s", "paper",
          "latency", "paper"],
    );
    for r in &rows {
        let (p_iops, p_bw, p_lat) = paper_row(r.access, r.dir, r.media);
        t.row(&[
            format!("{:?} {:?}", r.access, r.dir),
            r.media.to_string(),
            format!("{:.1}", r.kiops),
            format!("{p_iops:.1}"),
            format!("{:.2}", r.bandwidth_gib_s),
            format!("{p_bw:.2}"),
            format!("{}", r.latency),
            p_lat.to_string(),
        ]);
    }
    t.print();

    // Shape check: every class within 15 % of the paper's bandwidth and
    // PMEM dominating SSD 10×–100× in IOPS (the table's headline).
    for r in &rows {
        let (p_iops, p_bw, _) = paper_row(r.access, r.dir, r.media);
        assert!((r.bandwidth_gib_s - p_bw).abs() / p_bw < 0.15,
                "{:?} {:?} {} bandwidth off", r.access, r.dir, r.media);
        assert!((r.kiops - p_iops).abs() / p_iops < 0.35,
                "{:?} {:?} {} iops off: {} vs {}", r.access, r.dir, r.media,
                r.kiops, p_iops);
    }
    for pair in rows.chunks(2) {
        let speedup = pair[0].kiops / pair[1].kiops;
        // Paper's own worst ratio is rand-write 335/66.2 ≈ 5.1.
        assert!(speedup > 4.0, "PMEM/SSD speedup {speedup} too small");
    }
    println!("table2 OK: bandwidth within 15 %, 4.7–100x speedups hold");
}
