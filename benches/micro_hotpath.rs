//! Microbenchmarks of the hot paths (§Perf): PJRT combine batch
//! latency/throughput vs the pure-Rust oracle, DES event throughput,
//! and the tokenize+hash data plane rate that calibrates
//! `Workload::map_rate`.

use marvel::mapreduce::Workload;
use marvel::runtime::{default_artifacts_dir, RtEngine};
use marvel::sim::{Engine, SimNs, Stage};
use marvel::util::bench::{fmt_ns, Bench};
use marvel::util::rng::Rng;
use marvel::workloads::{Corpus, WordCount};

fn main() {
    let bench = Bench::new(3, 15);

    // -- PJRT combine batch vs oracle
    let dir = default_artifacts_dir();
    let mut pjrt = RtEngine::load(dir.as_deref()).expect("rt");
    let mut oracle = RtEngine::load(None).expect("oracle rt");
    let n = pjrt.batch_size();
    let mut rng = Rng::new(1);
    let hashes: Vec<i32> =
        (0..n).map(|_| (rng.next_u32() & 0x7fffffff) as i32).collect();
    let mask = vec![1f32; n];

    let r_p = bench.run("pjrt wordcount_combine (8192 tokens)", || {
        pjrt.wordcount_batch(&hashes, &mask).unwrap()
    });
    let r_o = bench.run("oracle wordcount_combine (8192 tokens)", || {
        oracle.wordcount_batch(&hashes, &mask).unwrap()
    });
    println!("{}", r_p.summary());
    println!("{}", r_o.summary());
    println!(
        "  pjrt tokens/s: {:.1} M   oracle tokens/s: {:.1} M   mode: {}",
        r_p.throughput(n as f64) / 1e6,
        r_o.throughput(n as f64) / 1e6,
        if pjrt.is_pjrt() { "PJRT" } else { "oracle-fallback" },
    );

    // -- tokenize+hash data plane (calibrates map_rate)
    let corpus = Corpus::new(10_000, 1.07);
    let mut rng = Rng::new(2);
    let text = corpus.generate(8_000_000, &mut rng);
    let r_t = bench.run("tokenize+hash 8 MB", || {
        text.split(|b| *b == b' ')
            .filter(|w| !w.is_empty())
            .map(marvel::util::hash::token_hash)
            .fold(0i64, |a, h| a + h as i64)
    });
    println!("{}", r_t.summary());
    println!("  data plane rate: {:.1} MB/s",
             r_t.throughput(8_000_000.0) / 1e6);

    // -- full map_split through the runtime (the real map hot path)
    let wc = WordCount::new(10_000, 1.07, &pjrt);
    let cfg = marvel::mapreduce::SystemConfig::marvel_igfs();
    let payload = marvel::storage::Payload::real(text.clone());
    let r_m = bench.run("map_split 8 MB (kernel combine)", || {
        wc.map_split(&payload, 32, &cfg, &mut pjrt, &mut Rng::new(3))
    });
    println!("{}", r_m.summary());
    println!("  map_split rate: {:.1} MB/s (feeds map_rate calibration)",
             r_m.throughput(8_000_000.0) / 1e6);

    // -- DES engine: events/second
    let r_e = bench.run("DES: 10k procs × 3 stages through 8 pools", || {
        let mut e = Engine::new();
        let pools: Vec<_> = (0..8).map(|_| e.add_pool(4)).collect();
        let bar = e.add_barrier(10_000);
        for i in 0..10_000u32 {
            let p = pools[(i % 8) as usize];
            e.spawn("t", vec![
                Stage::Acquire(p),
                Stage::Delay(SimNs::from_micros(10)),
                Stage::Release(p),
                Stage::Arrive(bar),
            ]);
        }
        e.run().unwrap()
    });
    println!("{}", r_e.summary());
    println!("  ≈{} per proc", fmt_ns(r_e.mean_ns / 10_000.0));

    // -- flow simulator: fan-in contention
    let r_f = bench.run("DES: 2000 concurrent flows on one link", || {
        let mut e = Engine::new();
        let link = e.add_resource("l", 1e9);
        for i in 0..2000u32 {
            e.spawn("f", vec![Stage::Flow {
                bytes: 1e6,
                path: vec![link],
                tag: i,
            }]);
        }
        e.run().unwrap()
    });
    println!("{}", r_f.summary());
    println!("micro_hotpath done");
}
