//! Microbenchmarks of the hot paths (§Perf): PJRT combine batch
//! latency/throughput vs the pure-Rust oracle, the tokenize+hash data
//! plane rate that calibrates `Workload::map_rate`, the full map_split
//! hot path serial vs the parallel map data plane, zero-copy payload
//! view ops, and DES event throughput.
//!
//! Emits `BENCH_micro_hotpath.json` (machine-readable; feeds PERF.md's
//! perf trajectory) next to the human-readable table.

use std::path::Path;

use marvel::mapreduce::{
    interm_key, interm_key_into, map_splits_parallel, PartitionPlan,
    reduce_partitions_parallel, SystemConfig, Workload,
};
use marvel::runtime::{default_artifacts_dir, RtEngine};
use marvel::sim::{Engine, SimNs, Stage};
use marvel::storage::Payload;
use marvel::util::bench::{fmt_ns, write_report, Bench, BenchResult};
use marvel::util::rng::Rng;
use marvel::workloads::{Corpus, WordCount};

fn main() {
    let bench = Bench::new(3, 15);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut metrics: Vec<(&str, f64)> = Vec::new();

    // -- PJRT combine batch vs oracle
    let dir = default_artifacts_dir();
    let mut pjrt = RtEngine::load(dir.as_deref()).expect("rt");
    let mut oracle = RtEngine::load(None).expect("oracle rt");
    let n = pjrt.batch_size();
    let mut rng = Rng::new(1);
    let hashes: Vec<i32> =
        (0..n).map(|_| (rng.next_u32() & 0x7fffffff) as i32).collect();
    let mask = vec![1f32; n];

    let r_p = bench.run("pjrt wordcount_combine (8192 tokens)", || {
        pjrt.wordcount_batch(&hashes, &mask).unwrap()
    });
    let r_o = bench.run("oracle wordcount_combine (8192 tokens)", || {
        oracle.wordcount_batch(&hashes, &mask).unwrap()
    });
    println!("{}", r_p.summary());
    println!("{}", r_o.summary());
    let pjrt_tok_s = r_p.throughput(n as f64);
    let oracle_tok_s = r_o.throughput(n as f64);
    println!(
        "  pjrt tokens/s: {:.1} M   oracle tokens/s: {:.1} M   mode: {}",
        pjrt_tok_s / 1e6,
        oracle_tok_s / 1e6,
        if pjrt.is_pjrt() { "PJRT" } else { "oracle-fallback" },
    );
    metrics.push(("pjrt_tokens_per_s", pjrt_tok_s));
    metrics.push(("oracle_tokens_per_s", oracle_tok_s));

    // -- tokenize+hash data plane (calibrates map_rate)
    let corpus = Corpus::new(10_000, 1.07);
    let mut rng = Rng::new(2);
    let text = corpus.generate(8_000_000, &mut rng);
    let r_t = bench.run("tokenize+hash 8 MB", || {
        text.split(|b| *b == b' ')
            .filter(|w| !w.is_empty())
            .map(marvel::util::hash::token_hash)
            .fold(0i64, |a, h| a + h as i64)
    });
    println!("{}", r_t.summary());
    let tok_mb_s = r_t.throughput(8_000_000.0) / 1e6;
    println!("  data plane rate: {tok_mb_s:.1} MB/s");
    metrics.push(("tokenize_hash_mb_per_s", tok_mb_s));

    // -- full map_split through the runtime (the real map hot path)
    let wc = WordCount::new(10_000, 1.07, &pjrt);
    let cfg = SystemConfig::marvel_igfs();
    let plan = PartitionPlan::hash(32);
    let payload = Payload::real(text.clone());
    let r_m = bench.run("map_split 8 MB (kernel combine)", || {
        wc.map_split(&payload, &plan, &cfg, &mut pjrt, &mut Rng::new(3))
    });
    println!("{}", r_m.summary());
    let ms_mb_s = r_m.throughput(8_000_000.0) / 1e6;
    println!("  map_split rate: {ms_mb_s:.1} MB/s (feeds map_rate calibration)");
    metrics.push(("map_split_mb_per_s", ms_mb_s));

    // -- parallel map data plane: 1 worker vs all cores over the same
    // splits (the driver's map phase minus the DES). Outputs must be
    // byte-identical at any worker count — asserted below.
    let n_workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4);
    let n_splits = 16usize;
    let split_bytes = 2_000_000u64;
    let splits: Vec<Payload> = (0..n_splits as u64)
        .map(|i| {
            Payload::real(corpus.generate(split_bytes,
                                          &mut Rng::new(100 + i)))
        })
        .collect();
    let plane_bytes = (n_splits as u64 * split_bytes) as f64;
    let r_s1 = bench.run("map plane 16×2 MB, 1 worker", || {
        map_splits_parallel(&wc, &splits, &plan, &cfg, &mut oracle, 7, 1)
    });
    let label = format!("map plane 16×2 MB, {n_workers} workers");
    let r_sn = bench.run(&label, || {
        map_splits_parallel(&wc, &splits, &plan, &cfg, &mut oracle, 7,
                            n_workers)
    });
    println!("{}", r_s1.summary());
    println!("{}", r_sn.summary());
    let serial_mb_s = r_s1.throughput(plane_bytes) / 1e6;
    let par_mb_s = r_sn.throughput(plane_bytes) / 1e6;
    let speedup = par_mb_s / serial_mb_s.max(1e-9);
    println!(
        "  map plane: serial {serial_mb_s:.1} MB/s → parallel \
         {par_mb_s:.1} MB/s ({speedup:.2}× on {n_workers} workers)"
    );
    metrics.push(("map_plane_serial_mb_per_s", serial_mb_s));
    metrics.push(("map_plane_parallel_mb_per_s", par_mb_s));
    metrics.push(("map_plane_speedup", speedup));
    metrics.push(("map_plane_workers", n_workers as f64));
    // Determinism: parallel output byte-identical to serial.
    let a = map_splits_parallel(&wc, &splits, &plan, &cfg, &mut oracle, 7,
                                1);
    let b = map_splits_parallel(&wc, &splits, &plan, &cfg, &mut oracle, 7,
                                n_workers);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.records, y.records);
        for (px, py) in x.partitions.iter().zip(&y.partitions) {
            assert_eq!(px.gather(), py.gather(),
                       "parallel map output diverged from serial");
        }
    }
    println!("  determinism: parallel output == serial output ✓");

    // -- parallel reduce data plane: every partition's inputs gathered
    // from the map outputs (zero-copy views), merged across partitions
    // by 1 worker vs all cores. Byte-identical at any count — asserted.
    let n_parts = 32usize;
    let inputs_per_part: Vec<Vec<marvel::storage::Payload>> = (0..n_parts)
        .map(|j| {
            a.iter()
                .map(|mo| mo.partitions[j].clone())
                .filter(|p| !p.is_empty())
                .collect()
        })
        .collect();
    let red_bytes: f64 = inputs_per_part
        .iter()
        .flatten()
        .map(|p| p.len() as f64)
        .sum();
    let r_r1 = bench.run("reduce plane 32 partitions, 1 worker", || {
        reduce_partitions_parallel(&wc, &inputs_per_part, n_parts, &cfg,
                                   &mut oracle, 1)
    });
    let label = format!("reduce plane 32 partitions, {n_workers} workers");
    let r_rn = bench.run(&label, || {
        reduce_partitions_parallel(&wc, &inputs_per_part, n_parts, &cfg,
                                   &mut oracle, n_workers)
    });
    println!("{}", r_r1.summary());
    println!("{}", r_rn.summary());
    let red_serial_mb_s = r_r1.throughput(red_bytes) / 1e6;
    let red_par_mb_s = r_rn.throughput(red_bytes) / 1e6;
    let red_speedup = red_par_mb_s / red_serial_mb_s.max(1e-9);
    println!(
        "  reduce plane: serial {red_serial_mb_s:.1} MB/s → parallel \
         {red_par_mb_s:.1} MB/s ({red_speedup:.2}× on {n_workers} workers)"
    );
    metrics.push(("reduce_plane_serial_mb_per_s", red_serial_mb_s));
    metrics.push(("reduce_plane_parallel_mb_per_s", red_par_mb_s));
    metrics.push(("reduce_plane_speedup", red_speedup));
    let ra = reduce_partitions_parallel(&wc, &inputs_per_part, n_parts,
                                        &cfg, &mut oracle, 1);
    let rb = reduce_partitions_parallel(&wc, &inputs_per_part, n_parts,
                                        &cfg, &mut oracle, n_workers);
    assert_eq!(ra.len(), rb.len());
    for (x, y) in ra.iter().zip(&rb) {
        assert_eq!(x.records, y.records);
        assert_eq!(x.output.gather(), y.output.gather(),
                   "parallel reduce output diverged from serial");
    }
    println!("  determinism: parallel reduce output == serial output ✓");

    // -- shuffle key formatting: fresh String per key (the pre-PR-10
    // driver loop) vs one reused buffer (`interm_key_into`). The driver
    // formats n_splits × n_reduces of these per stage.
    let r_kf = bench.run("interm_key ×32k, fresh alloc", || {
        let mut n = 0usize;
        for i in 0..1024usize {
            for j in 0..32usize {
                n += interm_key("bench/job", i, j).len();
            }
        }
        n
    });
    let r_kr = bench.run("interm_key ×32k, reused buffer", || {
        let mut buf = String::new();
        let mut n = 0usize;
        for i in 0..1024usize {
            for j in 0..32usize {
                interm_key_into(&mut buf, "bench/job", i, j);
                n += buf.len();
            }
        }
        n
    });
    println!("{}", r_kf.summary());
    println!("{}", r_kr.summary());
    println!(
        "  key format: fresh {} → reused {} per 32k keys",
        fmt_ns(r_kf.mean_ns),
        fmt_ns(r_kr.mean_ns)
    );
    metrics.push(("key_format_fresh_ns", r_kf.mean_ns));
    metrics.push(("key_format_reuse_ns", r_kr.mean_ns));

    // -- zero-copy payload plumbing: slice+concat as pure view ops
    // (pre-refactor this memcpy'd ~64 MB per iteration).
    let big = Payload::real(vec![7u8; 64 << 20]);
    let r_v = bench.run("payload: 1024 slices + concat of 64 MB", || {
        let views: Vec<Payload> = (0..1024u64)
            .map(|i| big.slice(i * 61_440, 65_536))
            .collect();
        Payload::concat(&views).len()
    });
    println!("{}", r_v.summary());
    metrics.push(("payload_view_assembly_ns", r_v.mean_ns));

    // -- DES engine: events/second
    let r_e = bench.run("DES: 10k procs × 3 stages through 8 pools", || {
        let mut e = Engine::new();
        let pools: Vec<_> = (0..8).map(|_| e.add_pool(4)).collect();
        let bar = e.add_barrier(10_000);
        for i in 0..10_000u32 {
            let p = pools[(i % 8) as usize];
            e.spawn("t", vec![
                Stage::Acquire(p),
                Stage::Delay(SimNs::from_micros(10)),
                Stage::Release(p),
                Stage::Arrive(bar),
            ]);
        }
        e.run().unwrap()
    });
    println!("{}", r_e.summary());
    println!("  ≈{} per proc", fmt_ns(r_e.mean_ns / 10_000.0));

    // -- flow simulator: fan-in contention
    let r_f = bench.run("DES: 2000 concurrent flows on one link", || {
        let mut e = Engine::new();
        let link = e.add_resource("l", 1e9);
        for i in 0..2000u32 {
            e.spawn("f", vec![Stage::Flow {
                bytes: 1e6,
                path: vec![link],
                tag: i,
                timeout: None,
            }]);
        }
        e.run().unwrap()
    });
    println!("{}", r_f.summary());

    results.extend([r_p, r_o, r_t, r_m, r_s1, r_sn, r_r1, r_rn, r_kf,
                    r_kr, r_v, r_e, r_f]);
    let refs: Vec<&BenchResult> = results.iter().collect();
    let out = Path::new("BENCH_micro_hotpath.json");
    match write_report(out, &refs, &metrics) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
    println!("micro_hotpath done");
}
