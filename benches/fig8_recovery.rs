//! Figure 8 (repo extension): checkpoint-based recovery under a
//! failure sweep — the paper's §4.3 future-work item quantified.
//!
//! One wordcount runs under increasing container-crash pressure in two
//! modes: *stateful* (tasks checkpoint (progress, partial aggregate)
//! into the IGFS state store and retries resume from the last
//! checkpoint) vs the *stateless* baseline (a failed function loses
//! "computation, state and data" and restarts from zero). Reported per
//! crash probability: recomputed bytes, task attempts, virtual
//! makespan, and checkpoint overhead. Outputs are byte-identical in
//! every cell (asserted). Emits `BENCH_fig8_recovery.json` through the
//! same `util::bench::write_report` flow `bench_diff.py` consumes.

use std::path::Path;

use marvel::coordinator::ClusterSpec;
use marvel::mapreduce::{run_job, stage_input, SystemConfig};
use marvel::runtime::RtEngine;
use marvel::util::bench::{write_report, Bench, BenchResult};
use marvel::util::bytes::MIB;
use marvel::workloads::WordCount;

const SEED: u64 = 42;
const FAILURE_SEED: u64 = 7;
const INPUT: u64 = 8 * MIB;

fn cfg_for(stateful: bool, crash_prob: f64) -> SystemConfig {
    let mut c = SystemConfig::marvel_igfs();
    c.failures.crash_prob = crash_prob;
    c.failures.max_failures_per_task = 2;
    c.failures.seed = FAILURE_SEED;
    c.recovery.max_attempts = 3;
    c.recovery.interval_bytes = 64 * 1024;
    c.recovery.stateful = stateful;
    c
}

struct Cell {
    recomputed: u64,
    attempts: u64,
    makespan_s: f64,
    ckpt_overhead_s: f64,
    output_bytes: u64,
}

fn run_cell(cfg: &SystemConfig) -> Cell {
    let mut rt = RtEngine::load(None).expect("rt");
    let mut cluster = ClusterSpec::default().deploy(cfg);
    cluster.stores.hdfs.block_size = 256 * 1024; // 32 splits from 8 MiB
    let wc = WordCount::new(10_000, 1.07, &rt);
    let input =
        stage_input(&mut cluster, cfg, &wc, INPUT, SEED).expect("stage");
    let r = run_job(&mut cluster, cfg, &wc, &input, &mut rt, SEED);
    assert!(r.ok(), "{:?}", r.failed);
    Cell {
        recomputed: r.recomputed_bytes,
        attempts: r.task_attempts,
        makespan_s: r.job_time.as_secs_f64(),
        ckpt_overhead_s: r.checkpoint_overhead.as_secs_f64(),
        output_bytes: r.output_bytes,
    }
}

fn main() {
    let bench = Bench::new(1, 3);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut metrics: Vec<(String, f64)> = Vec::new();

    let mut baseline_output = None;
    for &prob in &[0.0f64, 0.3, 0.6, 0.9] {
        let mut cells = Vec::new();
        for stateful in [true, false] {
            let mode = if stateful { "stateful" } else { "stateless" };
            let cfg = cfg_for(stateful, prob);
            let mut cell = None;
            let r = bench.run(
                &format!("wordcount 8 MiB, crash_prob={prob}, {mode}"),
                || {
                    let c = run_cell(&cfg);
                    let out = c.output_bytes;
                    cell = Some(c);
                    out
                },
            );
            println!("{}", r.summary());
            let cell = cell.expect("bench ran");
            // The recovery determinism contract, asserted per cell:
            // failures and recovery mode never move output bytes.
            match baseline_output {
                None => baseline_output = Some(cell.output_bytes),
                Some(b) => assert_eq!(
                    cell.output_bytes, b,
                    "outputs must be byte-count-identical at prob={prob}"
                ),
            }
            println!(
                "  {mode} p={prob}: {} attempts, {} B recomputed, \
                 {:.3} virtual s ({:.6} s checkpoint overhead)",
                cell.attempts, cell.recomputed, cell.makespan_s,
                cell.ckpt_overhead_s,
            );
            let tag = format!("p{:02}_{mode}", (prob * 10.0) as u32);
            metrics.push((format!("{tag}_recomputed_bytes"),
                          cell.recomputed as f64));
            metrics.push((format!("{tag}_task_attempts"),
                          cell.attempts as f64));
            metrics.push((format!("{tag}_virtual_makespan_s"),
                          cell.makespan_s));
            metrics.push((format!("{tag}_ckpt_overhead_s"),
                          cell.ckpt_overhead_s));
            cells.push(cell);
            results.push(r);
        }
        // The fig8 shape: wherever crashes actually happen, stateful
        // recovery recomputes no more than the stateless baseline.
        if prob > 0.0 {
            assert!(
                cells[0].recomputed <= cells[1].recomputed,
                "stateful recomputed {} > stateless {} at p={prob}",
                cells[0].recomputed,
                cells[1].recomputed
            );
        }
    }

    let refs: Vec<&BenchResult> = results.iter().collect();
    let met: Vec<(&str, f64)> =
        metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let out = Path::new("BENCH_fig8_recovery.json");
    match write_report(out, &refs, &met) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
    println!("fig8_recovery done");
}
