//! Ablation: IGFS DRAM capacity — the paper's §4.3 future-work design
//! ("Ignite on top of PMEM: persist intermediate data while serving it
//! from DRAM"). Shrinking the DRAM budget forces LRU demotion to the
//! PMEM backing tier; gets then pay PMEM latency instead of DRAM.

use marvel::coordinator::{ClusterSpec, Marvel};
use marvel::mapreduce::SystemConfig;
use marvel::util::bytes::{self, GIB, MIB};
use marvel::util::table::{fmt_secs, Table};
use marvel::workloads::WordCount;

const GB: u64 = 1_000_000_000;

fn main() {
    let mut m = Marvel::new(ClusterSpec::default(), 42).expect("marvel");
    let wc = WordCount::new(10_000, 1.07, &m.rt);
    let input = 2 * GB;

    let mut t = Table::new(
        "Ablation — IGFS DRAM capacity (WordCount 2 GB, raw shuffle)",
        &["igfs capacity", "job time", "dram hits", "pmem-tier hits",
          "evictions"],
    );
    let mut times = Vec::new();
    // Raw shuffle so intermediate (~11 GB) overwhelms small caches.
    for cap in [64 * GIB, 8 * GIB, 2 * GIB, 512 * MIB] {
        let mut cfg = SystemConfig::marvel_igfs_paper();
        cfg.igfs_capacity = cap;
        cfg.name = format!("igfs@{}", bytes::human(cap));
        // Fresh deployment per run happens inside Marvel::run; cache
        // stats come from the run's own cluster — re-derive via a
        // scoped run so stats are attributable.
        let mut cluster = m.spec.deploy(&cfg);
        let input_path = marvel::mapreduce::stage_input(
            &mut cluster, &cfg, &wc, input, m.seed).expect("stage");
        let r = marvel::mapreduce::run_job(
            &mut cluster, &cfg, &wc, &input_path, &mut m.rt, m.seed);
        assert!(r.ok(), "{}: {:?}", cfg.name, r.failed);
        let stats = cluster.stores.igfs.stats();
        times.push(r.job_time.as_secs_f64());
        t.row(&[
            bytes::human(cap),
            fmt_secs(r.job_time.as_secs_f64()),
            stats.hits_dram.to_string(),
            stats.hits_backing.to_string(),
            stats.evictions.to_string(),
        ]);
        if cap == 512 * MIB {
            assert!(stats.hits_backing > 0,
                    "tiny cache must demote to the PMEM tier");
        }
    }
    t.print();
    assert!(times.first().unwrap() <= times.last().unwrap(),
            "shrinking DRAM must not speed the job: {times:?}");
    println!("ablation_igfs_capacity OK");
}
